//! Seedable PRNG (xoshiro256++) — deterministic across platforms.
//!
//! Used everywhere randomness is needed: weight/test-tensor generation,
//! property-test case generation, and the synthetic request trace of the
//! serving demo. Deterministic seeding keeps every test and bench
//! reproducible run-to-run.

/// xoshiro256++ by Blackman & Vigna (public domain reference impl).
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
}

impl Prng {
    /// Create a generator from a seed via splitmix64 expansion.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform f32 in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform f32 in [lo, hi).
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform usize in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Prng::below(0)");
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-7);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Vector of uniforms in [lo, hi).
    pub fn range_vec(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.range(lo, hi)).collect()
    }

    /// Exponentially-distributed f64 with the given rate (for Poisson
    /// request-arrival traces in the serving demo).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        let u = (self.uniform() as f64).max(1e-12);
        -u.ln() / rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut p = Prng::new(7);
        for _ in 0..10_000 {
            let u = p.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut p = Prng::new(3);
        let xs: Vec<f32> = (0..50_000).map(|_| p.normal()).collect();
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_covers_range() {
        let mut p = Prng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[p.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
