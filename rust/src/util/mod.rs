//! Small self-contained utilities the rest of the crate builds on.
//!
//! The build environment is fully offline with only the `xla` crate's
//! dependency closure vendored, so the usual ecosystem crates (rand,
//! criterion, proptest, serde) are reimplemented here at the scale this
//! project needs: a seedable PRNG, streaming statistics and latency
//! histograms, an ASCII table printer for the bench harnesses, and a
//! miniature property-testing framework.

pub mod bench;
pub mod corpus;
pub mod f16;
pub mod json;
pub mod prng;
pub mod quickcheck;
pub mod stats;
pub mod table;

pub use prng::Prng;
pub use stats::{percentile, LatencyHistogram, Summary};
pub use table::Table;
