//! ASCII table printer for bench harnesses and CLI reports.
//!
//! The bench binaries regenerate the paper's tables/figures as text; this
//! keeps their output layout consistent and diff-able in EXPERIMENTS.md.

/// A right-padded ASCII table with a header row and a rule under it.
#[derive(Clone, Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: Option<String>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: None,
        }
    }

    pub fn with_title(mut self, title: &str) -> Self {
        self.title = Some(title.to_string());
        self
    }

    /// Add a row; panics if the column count mismatches the header.
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Convenience: row from &str slices.
    pub fn row_str(&mut self, cells: &[&str]) {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render to a string (also what `Display` prints).
    pub fn render(&self) -> String {
        let cols = self.header.len();
        let mut width = vec![0usize; cols];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(&format!("== {t} ==\n"));
        }
        let fmt_row = |cells: &[String], width: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = width[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.header, &width));
        out.push('\n');
        out.push_str(&"-".repeat(width.iter().sum::<usize>() + 2 * (cols - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &width));
            out.push('\n');
        }
        out
    }
}

impl std::fmt::Display for Table {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.render())
    }
}

/// Format a nanosecond duration with an adaptive unit.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_columns() {
        let mut t = Table::new(&["op", "cycles"]);
        t.row_str(&["MatMul", "123"]);
        t.row_str(&["CumSum", "4567890"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert!(lines[0].starts_with("op      cycles"));
        assert_eq!(lines.len(), 4);
    }

    #[test]
    #[should_panic]
    fn wrong_arity_panics() {
        let mut t = Table::new(&["a", "b"]);
        t.row_str(&["only-one"]);
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 us");
        assert_eq!(fmt_ns(2.5e6), "2.50 ms");
        assert_eq!(fmt_ns(3.2e9), "3.20 s");
    }
}
