//! Command-line interface (hand-rolled: no clap in the vendored set).
//!
//! Subcommands:
//!   serve    — start the PJRT-backed server, read prompts from stdin
//!   profile  — NPU-simulator latency breakdown of a model graph
//!   census   — Fig-5 operator census (Mamba vs Mamba-2)
//!   plu-fit  — fit & report a C-LUT for silu/softplus
//!   verify   — differential-check the XAMBA passes on a model graph

mod args;

pub use args::Args;

use crate::config::{self, presets, NpuConfig, ServeConfig};
use crate::coordinator::{
    start_backend, start_planned_router, GenParams, Metrics, Response, Router, Server,
};
use crate::graph::Census;
use crate::npu::Profile;
use crate::passes::{actiba::ActibaPass, cumba::CumbaPass, reduba::RedubaPass, Pass};
use crate::plu;

/// Entry point: dispatch on the first positional argument.
pub fn run(argv: &[String]) -> Result<(), String> {
    let args = Args::parse(argv)?;
    match args.command.as_str() {
        "serve" => cmd_serve(&args),
        "profile" => cmd_profile(&args),
        "census" => cmd_census(&args),
        "plu-fit" => cmd_plu_fit(&args),
        "verify" => cmd_verify(&args),
        "quality" => cmd_quality(&args),
        "bench-check" => cmd_bench_check(&args),
        "help" | "" => {
            print!("{}", HELP);
            Ok(())
        }
        other => Err(format!("unknown command {other:?}; try `xamba help`")),
    }
}

const HELP: &str = "\
xamba — SSMs on resource-constrained NPUs (paper reproduction)

USAGE: xamba <command> [--flag value ...]

COMMANDS:
  serve     --model tiny-mamba|tiny-mamba2 --variant xamba
            [--backend planned|pjrt] [--dtype f32|f16|i8]
            [--artifacts DIR] [--weights FILE]
            [--window 32] [--workers 0] [--buckets 1,2,4,8]
            [--prefill-buckets 1,2,4,8] [--steal-chunk 0]
            [--prefix-cache-mb 32] [--prefill-chunk 0]
            [--max-batch-total-tokens 0] [--waiting-served-ratio 0.0]
            [--deadline-ms 0]
            [--replicas 1] [--replica-dtypes f32,f16,i8,i8]
            [--replica-workers 2,2,1,1] [--replica-inflight 32]
            [--speculate 0]
            [--max-new 48] [--temperature 0.0]
            reads prompts from stdin (one per line), prints completions;
            the default planned backend serves BOTH model families
            (mamba-1 and mamba-2) and needs no artifacts (untrained
            weights are random-initialized when no .bin file is found).
            --dtype picks the serving precision (planned backend only):
            f16 halves weight bytes, i8 runs the projection GEMMs on
            int8 with dynamic activation scales; --prefill-buckets
            batches concurrent admissions into one prefill graph call
            per length-class (cuts TTFT under load); --steal-chunk sets
            the pool's work-stealing decode chunk (0 = auto);
            --prefix-cache-mb budgets the cross-request prefix cache
            (finished states resume follow-up turns in O(new tokens);
            0 disables); --prefill-chunk streams long prompts through
            fixed-size chunk graphs with bounded arena memory (0 = off);
            --max-batch-total-tokens caps the token budget (prompt +
            max-new headroom) held by live sequences (0 = unbounded),
            --waiting-served-ratio defers admission until the queue is
            that many times the running batch (0 = admit eagerly), and
            --deadline-ms finishes requests as DeadlineExceeded past a
            wall-clock deadline (0 = none);
            --replicas > 1 starts a router over that many independent
            engines (least-loaded dispatch, session affinity, failover;
            planned backend only), --replica-dtypes / --replica-workers
            give per-replica overrides for heterogeneous fleets (one
            entry per replica), and --replica-inflight caps dispatched
            requests per replica (keep <= queue_cap; 0 = uncapped);
            --speculate K drafts up to K tokens per decode step via
            prompt-lookup and verifies them in one batched step (greedy
            requests, planned backend, f32/f16; output stays bitwise
            identical to --speculate 0)
  profile   --model block130m-mamba2 [--t 4] [--passes cumba,reduba,actiba]
            [--config FILE] [--pipelined] [--energy]
            simulated-NPU per-op latency breakdown
  census    [--t 4]           Fig-5 operator census, Mamba vs Mamba-2
  plu-fit   [--fn silu|softplus] [--segments 32] [--adaptive]
  verify    --model tiny-mamba2 [--t 16]   differential pass verification
  quality   --model tiny-mamba [--dtype f16|i8] [--window 16]
            [--windows 8] [--weights FILE] [--workers 1]
            [--budget 0.05]
            evaluate LM quality (perplexity / top-1 / logit drift) at a
            serving dtype against the f32 path; with --budget, exits
            non-zero when the quantized perplexity regresses past the
            given fraction (the CI quality-smoke gate)
  bench-check --pr BENCH_pr.json --baseline benches/baseline_serve.json
            [--max-regress 0.20] [--summary FILE]
            compare a bench metrics file against the committed baseline;
            exits non-zero on any tokens/sec or TTFT regression past the
            tolerance (the CI bench-smoke gate); --summary also writes
            the delta table as markdown (floor, PR value, % delta,
            pass/fail) for the CI job summary, even when the gate fails
  help
";

fn npu_from(args: &Args) -> Result<NpuConfig, String> {
    let doc = config::load(args.get("config"))?;
    Ok(NpuConfig::from_doc(&doc, "npu"))
}

fn parse_usize_list(flag: &str, list: &str, what: &str) -> Result<Vec<usize>, String> {
    list.split(',')
        .map(|s| {
            s.trim()
                .parse::<usize>()
                .map_err(|_| format!("--{flag}: {s:?} is not a {what}"))
        })
        .collect()
}

fn cmd_serve(args: &Args) -> Result<(), String> {
    let mut cfg = ServeConfig::default();
    if let Some(b) = args.get("backend") {
        cfg.backend = b.to_string();
    }
    if let Some(d) = args.get("artifacts") {
        cfg.artifacts_dir = d.to_string();
    }
    if let Some(m) = args.get("model") {
        cfg.model = m.to_string();
    }
    if let Some(v) = args.get("variant") {
        cfg.variant = v.to_string();
    }
    if let Some(d) = args.get("dtype") {
        cfg.dtype = d.to_string();
    }
    if let Some(w) = args.get("weights") {
        cfg.weights_path = w.to_string();
    }
    if let Some(w) = args.get_usize("window") {
        cfg.prefill_window = w;
    }
    if let Some(w) = args.get_usize("workers") {
        cfg.workers = w;
    }
    if let Some(list) = args.get("buckets") {
        cfg.decode_buckets = parse_usize_list("buckets", list, "batch size")?;
    }
    if let Some(list) = args.get("prefill-buckets") {
        cfg.prefill_buckets = parse_usize_list("prefill-buckets", list, "batch size")?;
    }
    if let Some(v) = args.get("steal-chunk") {
        cfg.steal_chunk = v
            .parse::<usize>()
            .map_err(|_| format!("--steal-chunk: {v:?} is not a chunk size"))?;
    }
    if let Some(v) = args.get_usize("prefix-cache-mb") {
        cfg.prefix_cache_mb = v;
    }
    if let Some(v) = args.get_usize("prefill-chunk") {
        cfg.prefill_chunk = v;
    }
    // scheduler knobs apply to BOTH backends: they shape the engine
    // loop's admission policy, not the executor
    if let Some(v) = args.get_usize("max-batch-total-tokens") {
        cfg.max_batch_total_tokens = v;
    }
    if let Some(v) = args.get("waiting-served-ratio") {
        cfg.waiting_served_ratio = v
            .parse::<f64>()
            .map_err(|_| format!("--waiting-served-ratio: {v:?} is not a ratio"))?;
    }
    if let Some(v) = args.get_usize("deadline-ms") {
        cfg.deadline_ms = v as u64;
    }
    // replica fleet knobs (router in front of N engines)
    if let Some(v) = args.get_usize("replicas") {
        cfg.replicas = v;
    }
    if let Some(list) = args.get("replica-dtypes") {
        cfg.replica_dtypes = list
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect();
    }
    if let Some(list) = args.get("replica-workers") {
        cfg.replica_workers =
            parse_usize_list("replica-workers", list, "worker count")?;
    }
    if let Some(v) = args.get_usize("replica-inflight") {
        cfg.replica_inflight = v;
    }
    // parsed signed so "--speculate -1" reaches validate's message
    // instead of failing as "not a number" here
    if let Some(v) = args.get("speculate") {
        cfg.speculate = v
            .parse::<i64>()
            .map_err(|_| format!("--speculate: {v:?} is not a draft length"))?;
    }
    if cfg.backend == "pjrt" {
        for flag in [
            "weights",
            "window",
            "workers",
            "prefill-buckets",
            "steal-chunk",
            "prefix-cache-mb",
            "prefill-chunk",
        ] {
            // --dtype is validated (not just warned about): see
            // ServeConfig::validate via start_backend
            if args.get(flag).is_some() {
                eprintln!(
                    "warning: --{flag} only applies to the planned backend; \
                     the pjrt backend takes it from the manifest"
                );
            }
        }
    }
    let max_new = args.get_usize("max-new").unwrap_or(48);
    let temperature = args.get_f32("temperature").unwrap_or(0.0);

    // one engine, or a router over N of them — same client surface
    enum Frontend {
        Single(Server),
        Fleet(Router),
    }
    impl Frontend {
        fn submit(
            &self,
            prompt: &[u8],
            params: GenParams,
        ) -> std::sync::mpsc::Receiver<Response> {
            match self {
                Frontend::Single(s) => s.submit(prompt, params),
                Frontend::Fleet(r) => r.submit(prompt, params),
            }
        }
        fn shutdown(self) -> Metrics {
            match self {
                Frontend::Single(s) => s.shutdown(),
                Frontend::Fleet(r) => r.shutdown(),
            }
        }
    }
    let server = if cfg.replicas > 1 {
        if cfg.backend == "pjrt" {
            return Err(
                "replicated serving (--replicas > 1) runs on the planned backend"
                    .into(),
            );
        }
        Frontend::Fleet(start_planned_router(&cfg).map_err(|e| format!("{e:#}"))?)
    } else {
        Frontend::Single(start_backend(&cfg).map_err(|e| format!("{e:#}"))?)
    };
    eprintln!(
        "serving {} ({}, dtype {}) on the {} backend{} — type a prompt per line, \
         ctrl-d to stop",
        cfg.model,
        cfg.variant,
        if cfg.dtype.is_empty() { "f32" } else { &cfg.dtype },
        cfg.backend,
        if cfg.replicas > 1 {
            format!(" x {} replicas", cfg.replicas)
        } else {
            String::new()
        }
    );
    let stdin = std::io::stdin();
    let mut line = String::new();
    loop {
        line.clear();
        if std::io::BufRead::read_line(&mut stdin.lock(), &mut line)
            .map_err(|e| e.to_string())?
            == 0
        {
            break;
        }
        let prompt = line.trim_end();
        if prompt.is_empty() {
            continue;
        }
        let rx = server.submit(
            prompt.as_bytes(),
            GenParams { max_new_tokens: max_new, temperature, ..Default::default() },
        );
        match rx.recv() {
            Ok(r) => println!(
                "{}{}   [{:?}, ttft {:.1} ms, {:.0} tok/s]",
                prompt,
                String::from_utf8_lossy(&r.generated),
                r.finish,
                r.ttft_us / 1e3,
                r.decode_tokens_per_s()
            ),
            Err(_) => return Err("server died".into()),
        }
    }
    let m = server.shutdown();
    eprintln!("{}", m.report());
    Ok(())
}

fn cmd_profile(args: &Args) -> Result<(), String> {
    let name = args.get("model").unwrap_or("block130m-mamba2");
    let shape = presets::model_by_name(name).ok_or(format!("unknown model {name}"))?;
    let t = args.get_usize("t").unwrap_or(4);
    let cfg = npu_from(args)?;
    let mut g = if shape.n_layers == 1 {
        crate::models::build_block(&shape, t)
    } else {
        crate::models::build_prefill(&shape, t)
    };
    let base = Profile::of(&cfg, &g);
    println!("{}", base.breakdown_table());
    if args.has("pipelined") {
        let r = crate::npu::pipelined_latency(&cfg, &g);
        println!(
            "pipelined makespan {} (overlap {:.2}x, critical path {})",
            crate::util::table::fmt_ns(r.makespan_ns),
            r.overlap(),
            crate::util::table::fmt_ns(r.critical_path_ns),
        );
    }
    if args.has("energy") {
        let e = crate::npu::estimate_energy(&cfg, &g, &Default::default());
        println!(
            "energy: {:.0} uJ (compute {:.0}, SRAM {:.0}, DRAM {:.0})",
            e.total_uj(), e.compute_uj, e.sram_uj, e.dram_uj
        );
    }
    if let Some(pass_list) = args.get("passes") {
        for p in pass_list.split(',') {
            g = match p {
                "cumba" => CumbaPass.apply(&g),
                "reduba" => RedubaPass.apply(&g),
                "actiba" => ActibaPass::default().apply(&g),
                other => return Err(format!("unknown pass {other}")),
            };
        }
        let opt = Profile::of(&cfg, &g);
        println!("{}", opt.breakdown_table());
        println!(
            "speedup with [{}]: {:.2}x",
            pass_list,
            base.total_ns / opt.total_ns
        );
    }
    Ok(())
}

fn cmd_census(args: &Args) -> Result<(), String> {
    let t = args.get_usize("t").unwrap_or(4);
    let c1 = Census::of(&crate::models::build_block(&presets::block130m_mamba(), t));
    let c2 = Census::of(&crate::models::build_block(&presets::block130m_mamba2(), t));
    println!(
        "{}",
        Census::comparison_table(&[
            (&format!("mamba(T={t})"), &c1),
            (&format!("mamba2(T={t})"), &c2),
        ])
    );
    Ok(())
}

fn cmd_plu_fit(args: &Args) -> Result<(), String> {
    let f = args.get("fn").unwrap_or("silu");
    let segments = args.get_usize("segments").unwrap_or(32);
    let adaptive = args.has("adaptive");
    let (table_err, ada_err) = match f {
        "silu" => (
            plu::silu_table(segments, -8.0, 8.0).max_abs_error(plu::silu_exact, 4.0),
            plu::fit_adaptive(plu::silu_exact, -8.0, 8.0, segments)
                .max_abs_error(plu::silu_exact),
        ),
        "softplus" => (
            plu::softplus_table(segments, -8.0, 8.0)
                .max_abs_error(plu::softplus_exact, 4.0),
            plu::fit_adaptive(plu::softplus_exact, -8.0, 8.0, segments)
                .max_abs_error(plu::softplus_exact),
        ),
        other => return Err(format!("unknown fn {other}")),
    };
    println!("fn={f} segments={segments}");
    println!("uniform C-LUT   max |err| = {table_err:.3e}");
    if adaptive {
        println!("adaptive C-LUT  max |err| = {ada_err:.3e} (Flex-SFU-style)");
    }
    Ok(())
}

fn cmd_bench_check(args: &Args) -> Result<(), String> {
    let pr = args.get("pr").ok_or("bench-check needs --pr FILE")?;
    let baseline = args
        .get("baseline")
        .ok_or("bench-check needs --baseline FILE")?;
    let tolerance = args.get_f32("max-regress").unwrap_or(0.20) as f64;
    let checks = crate::util::bench::check_files(pr, baseline, tolerance)?;
    // write the markdown delta table BEFORE the pass/fail verdict so CI
    // can publish it to the job summary even when the gate fails
    if let Some(path) = args.get("summary") {
        let md = crate::util::bench::summary_markdown(&checks, tolerance);
        std::fs::write(path, md).map_err(|e| format!("--summary {path}: {e}"))?;
    }
    let mut table = crate::util::Table::new(&["metric", "baseline", "pr", "change", "ok"])
        .with_title(&format!("bench regression gate (tolerance {:.0}%)", tolerance * 100.0));
    let mut regressed = Vec::new();
    for c in &checks {
        table.row(&[
            c.key.clone(),
            format!("{:.2}", c.baseline),
            format!("{:.2}", c.got),
            format!("{:+.1}%", c.change_pct),
            if c.regressed { "REGRESSED".into() } else { "ok".into() },
        ]);
        if c.regressed {
            regressed.push(c.key.clone());
        }
    }
    println!("{}", table.render());
    if regressed.is_empty() {
        println!("bench-check: {} metrics within tolerance", checks.len());
        Ok(())
    } else {
        Err(format!(
            "bench-check: {} of {} metrics regressed more than {:.0}%: {}",
            regressed.len(),
            checks.len(),
            tolerance * 100.0,
            regressed.join(", ")
        ))
    }
}

fn cmd_quality(args: &Args) -> Result<(), String> {
    use crate::graph::tensor::DType;

    let name = args.get("model").unwrap_or("tiny-mamba");
    let shape = presets::model_by_name(name).ok_or(format!("unknown model {name}"))?;
    let dtype_str = args.get("dtype").unwrap_or("i8");
    let dtype = DType::parse_serve(dtype_str)
        .ok_or(format!("--dtype {dtype_str:?} unsupported (want f32, f16, or i8)"))?;
    let window = args.get_usize("window").unwrap_or(16);
    let windows = args.get_usize("windows").unwrap_or(8);
    let workers = args.get_usize("workers").unwrap_or(1);
    let weights = match args.get("weights") {
        Some(path) => crate::models::params::load_f32_bin(path)?,
        None => crate::coordinator::PlannedServeModel::random_weights(&shape, 42),
    };
    let graph = crate::models::build_prefill(&shape, window);
    let text = crate::util::corpus::corpus(windows * (window + 1) + window, 1234);

    let (exact, logits) = crate::quality::eval_lm(
        &shape, &graph, &weights, &text, window, windows, None, workers,
    )?;
    let (quant, _) = crate::quality::eval_lm_dtyped(
        &shape,
        &graph,
        &weights,
        dtype,
        &text,
        window,
        windows,
        Some(&logits),
        workers,
    )?;

    let mut table = crate::util::Table::new(&["variant", "ppl", "top1", "logit mae", "logit max"])
        .with_title(&format!(
            "quality: {} over {} windows of {} (f32 vs {})",
            shape.name,
            exact.windows,
            window,
            dtype.name()
        ));
    table.row(&[
        "f32".into(),
        format!("{:.4}", exact.ppl),
        format!("{:.4}", exact.top1),
        "0".into(),
        "0".into(),
    ]);
    table.row(&[
        dtype.name().into(),
        format!("{:.4}", quant.ppl),
        format!("{:.4}", quant.top1),
        format!("{:.3e}", quant.logit_mae),
        format!("{:.3e}", quant.logit_max),
    ]);
    println!("{}", table.render());
    let delta = (quant.ppl - exact.ppl) / exact.ppl;
    println!(
        "ppl delta vs f32: {:+.3}% (top1 {:+.4})",
        delta * 100.0,
        quant.top1 - exact.top1
    );
    if let Some(budget) = args.get_f32("budget") {
        if delta > budget as f64 {
            return Err(format!(
                "quality: {} perplexity regressed {:.3}% past the {:.3}% budget",
                dtype.name(),
                delta * 100.0,
                budget * 100.0
            ));
        }
        println!(
            "quality: {} ppl delta {:+.3}% within the {:.3}% budget",
            dtype.name(),
            delta * 100.0,
            budget * 100.0
        );
    }
    Ok(())
}

fn cmd_verify(args: &Args) -> Result<(), String> {
    let name = args.get("model").unwrap_or("tiny-mamba2");
    let shape = presets::model_by_name(name).ok_or(format!("unknown model {name}"))?;
    let t = args.get_usize("t").unwrap_or(16);
    let g = crate::models::build_block(&shape, t);
    for (label, rewritten) in [
        ("cumba", CumbaPass.apply(&g)),
        ("reduba", RedubaPass.apply(&g)),
        ("cumba+reduba", RedubaPass.apply(&CumbaPass.apply(&g))),
        ("actiba", ActibaPass::default().apply(&g)),
    ] {
        let r = crate::passes::verify::differential(&g, &rewritten, 2, 99, 0.3)?;
        println!(
            "{label:14} outputs={} max_abs_err={:.3e} max_rel_err={:.3e}",
            r.outputs, r.max_abs_err, r.max_rel_err
        );
    }
    Ok(())
}
