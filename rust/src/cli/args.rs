//! Tiny `--flag value` / `--flag` argument parser.

use std::collections::BTreeMap;

/// Parsed command line: one positional command + string flags.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse `argv` (without the binary name).
    pub fn parse(argv: &[String]) -> Result<Args, String> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    return Err("bare -- not supported".into());
                }
                // --k=v or --k v or --switch
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    out.flags.insert(name.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    out.switches.push(name.to_string());
                }
            } else if out.command.is_empty() {
                out.command = a.clone();
            } else {
                return Err(format!("unexpected positional argument {a:?}"));
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str) -> Option<usize> {
        self.get(key).and_then(|v| v.parse().ok())
    }

    pub fn get_f32(&self, key: &str) -> Option<f32> {
        self.get(key).and_then(|v| v.parse().ok())
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_command_flags_switches() {
        let a = Args::parse(&sv(&[
            "profile", "--model", "tiny-mamba", "--t=8", "--adaptive",
        ]))
        .unwrap();
        assert_eq!(a.command, "profile");
        assert_eq!(a.get("model"), Some("tiny-mamba"));
        assert_eq!(a.get_usize("t"), Some(8));
        assert!(a.has("adaptive"));
        assert!(!a.has("nope"));
    }

    #[test]
    fn rejects_extra_positionals() {
        assert!(Args::parse(&sv(&["a", "b"])).is_err());
    }

    #[test]
    fn empty_is_help() {
        let a = Args::parse(&[]).unwrap();
        assert_eq!(a.command, "");
    }
}
