//! NPU cost-model simulator — the substitute for the paper's Intel®
//! Core™ Ultra Series 2 NPU (DESIGN.md §1).
//!
//! The model keeps the architectural split the paper's analysis rests on:
//! a high-frequency output-stationary MPU MAC array for matrix ops, a
//! slower vector DSP for sequential ops (CumSum, ReduceSum) and
//! transcendental activations (Swish, Softplus), a drain-path PLU for
//! piecewise-linear evaluation, and an SRAM/DRAM hierarchy with ZVC-
//! compressed mask traffic and sparsity-bitmap compute skip (Fig 3).
//!
//! `Profile::of(cfg, graph)` prices every live node; the `benches/`
//! harnesses turn profiles into the paper's figures.

pub mod cost;
pub mod energy;
pub mod profile;
pub mod schedule;
pub mod zvc;

pub use cost::{node_cost, Engine, NodeCost};
pub use energy::{estimate as estimate_energy, EnergyModel, EnergyReport};
pub use profile::{NodeRecord, OpAggregate, Profile};
pub use schedule::{pipelined_latency, ScheduleResult};
