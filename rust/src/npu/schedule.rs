//! Execution-order scheduling of a profiled graph across NPU engines.
//!
//! The base `Profile` sums node latencies (strictly sequential issue —
//! how a simple runtime walks a command list). Real NPUs overlap engines:
//! while the DSP grinds through a CumSum, the MPU can run an independent
//! MatMul. `pipelined_latency` computes the dataflow-constrained makespan:
//! each node starts when its inputs are done AND its engine is free —
//! list scheduling over {MPU, DSP, PLU, DMA} with dependency edges from
//! the graph.
//!
//! The `ablation_pipeline` bench uses this to show the paper's speedups
//! are *not* an artifact of sequential-issue assumptions: CumBA helps the
//! overlapped schedule almost as much, because everything downstream of
//! segsum depends on CumSum_b (it sits on the critical path).

use crate::config::NpuConfig;
use crate::graph::Graph;

use super::cost::{node_cost, Engine};

/// Result of list-scheduling a graph onto the engines.
#[derive(Clone, Debug)]
pub struct ScheduleResult {
    /// Dataflow + engine-constrained makespan (ns).
    pub makespan_ns: f64,
    /// Sum of node latencies (the sequential-issue model).
    pub sequential_ns: f64,
    /// Per-engine busy time (ns).
    pub engine_busy_ns: Vec<(&'static str, f64)>,
    /// Length of the pure dependency critical path (ns), engines infinite.
    pub critical_path_ns: f64,
}

impl ScheduleResult {
    /// Overlap factor: sequential / makespan (1.0 = no overlap benefit).
    pub fn overlap(&self) -> f64 {
        if self.makespan_ns == 0.0 {
            1.0
        } else {
            self.sequential_ns / self.makespan_ns
        }
    }
}

/// List-schedule the live nodes of `graph` over the four engines.
pub fn pipelined_latency(cfg: &NpuConfig, graph: &Graph) -> ScheduleResult {
    let live = graph.live_set();
    let n = graph.nodes.len();
    let mut dur = vec![0.0f64; n];
    let mut engine = vec![Engine::Dma; n];
    let mut sequential = 0.0;
    for node in &graph.nodes {
        if !live[node.id] {
            continue;
        }
        let c = node_cost(cfg, graph, node);
        dur[node.id] = c.total_ns;
        engine[node.id] = c.engine;
        sequential += c.total_ns;
    }

    // earliest-start respecting dependencies + engine serialization.
    // nodes are in topological id order already; engines process in that
    // priority order (list scheduling).
    let mut finish = vec![0.0f64; n];
    let mut engine_free = [0.0f64; 4]; // MPU, DSP, PLU, DMA
    let mut engine_busy = [0.0f64; 4];
    let idx = |e: Engine| match e {
        Engine::Mpu => 0usize,
        Engine::Dsp => 1,
        Engine::PluDrain => 2,
        Engine::Dma => 3,
    };
    // pure critical path (infinite engines)
    let mut cp_finish = vec![0.0f64; n];
    for node in &graph.nodes {
        if !live[node.id] {
            continue;
        }
        let ready = node
            .inputs
            .iter()
            .map(|&i| finish[i])
            .fold(0.0f64, f64::max);
        let e = idx(engine[node.id]);
        let start = ready.max(engine_free[e]);
        finish[node.id] = start + dur[node.id];
        engine_free[e] = finish[node.id];
        engine_busy[e] += dur[node.id];

        let cp_ready = node
            .inputs
            .iter()
            .map(|&i| cp_finish[i])
            .fold(0.0f64, f64::max);
        cp_finish[node.id] = cp_ready + dur[node.id];
    }
    let makespan = graph
        .outputs
        .iter()
        .map(|&o| finish[o])
        .fold(engine_free.iter().cloned().fold(0.0, f64::max), f64::max);
    let critical = cp_finish.iter().cloned().fold(0.0, f64::max);
    ScheduleResult {
        makespan_ns: makespan,
        sequential_ns: sequential,
        engine_busy_ns: vec![
            ("MPU", engine_busy[0]),
            ("DSP", engine_busy[1]),
            ("PLU", engine_busy[2]),
            ("DMA", engine_busy[3]),
        ],
        critical_path_ns: critical,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{npu_series2, npu_unit};
    use crate::graph::Graph;

    #[test]
    fn independent_work_overlaps_dependent_does_not() {
        let cfg = npu_series2();
        // two independent chains: matmul (MPU) and softplus (DSP)
        let mut g = Graph::new("par");
        let a = g.input("a", vec![256, 256]);
        let b = g.input("b", vec![256, 256]);
        let m = g.matmul(a, b, "mm");
        let s = g.softplus(a, "sp");
        g.output(m);
        g.output(s);
        let r = pipelined_latency(&cfg, &g);
        assert!(r.makespan_ns < r.sequential_ns * 0.999, "no overlap found");

        // strictly dependent chain: no overlap possible
        let mut g2 = Graph::new("seq");
        let a2 = g2.input("a", vec![256, 256]);
        let b2 = g2.input("b", vec![256, 256]);
        let m2 = g2.matmul(a2, b2, "mm");
        let s2 = g2.softplus(m2, "sp");
        g2.output(s2);
        let r2 = pipelined_latency(&cfg, &g2);
        assert!((r2.makespan_ns - r2.sequential_ns).abs() < 1e-6);
    }

    #[test]
    fn makespan_bounded_by_critical_path_and_sequential() {
        let cfg = npu_series2();
        let g = crate::models::build_block(
            &crate::config::presets::block130m_mamba2(),
            4,
        );
        let r = pipelined_latency(&cfg, &g);
        assert!(r.makespan_ns <= r.sequential_ns + 1e-6);
        assert!(r.makespan_ns >= r.critical_path_ns - 1e-6);
        assert!(r.overlap() >= 1.0);
    }

    #[test]
    fn unit_npu_hand_example() {
        // A->B (same engine) and C independent on another engine
        let cfg = npu_unit();
        let mut g = Graph::new("h");
        let x = g.input("x", vec![4, 4]);
        let w = g.input("w", vec![4, 4]);
        let m1 = g.matmul(x, w, "m1"); // MPU 64 cycles = 64 ns
        let m2 = g.matmul(m1, w, "m2"); // MPU, depends on m1
        let sp = g.softplus(x, "sp"); // DSP 16 ns, independent
        g.output(m2);
        g.output(sp);
        let r = pipelined_latency(&cfg, &g);
        // both matmuls memory-bound on unit npu: mem = in+out bytes
        // just check structure: makespan < sequential, >= each chain
        assert!(r.makespan_ns < r.sequential_ns);
        assert!(r.engine_busy_ns[0].1 > 0.0 && r.engine_busy_ns[1].1 > 0.0);
    }
}
