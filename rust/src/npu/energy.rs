//! Energy model: pJ-level accounting over a profiled graph.
//!
//! The paper motivates NPUs by energy efficiency ("improved energy
//! efficiency", §1) without publishing energy numbers; this model makes
//! the claim quantitative for our experiments: MAC energy on the MPU,
//! per-element DSP op energy (a DSP op costs more than a MAC at the same
//! element count — instruction overhead), and the dominant term, memory:
//! SRAM vs DRAM access energy per byte (DRAM ~20x SRAM, standard 45/7 nm
//! ballpark figures).

use crate::config::NpuConfig;
use crate::graph::Graph;

use super::cost::Engine;
use super::profile::Profile;

/// Energy cost constants (picojoules). Ballpark LPDDR5 + 7 nm figures;
/// relative magnitudes are what the experiments depend on.
#[derive(Clone, Debug)]
pub struct EnergyModel {
    pub pj_per_mac: f64,
    pub pj_per_dsp_cycle: f64,
    pub pj_per_plu_elem: f64,
    pub pj_per_sram_byte: f64,
    pub pj_per_dram_byte: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        Self {
            pj_per_mac: 0.2,
            pj_per_dsp_cycle: 2.0,
            pj_per_plu_elem: 0.1,
            pj_per_sram_byte: 1.0,
            pj_per_dram_byte: 20.0,
        }
    }
}

/// Energy breakdown of one graph execution (microjoules).
#[derive(Clone, Debug, Default)]
pub struct EnergyReport {
    pub compute_uj: f64,
    pub sram_uj: f64,
    pub dram_uj: f64,
}

impl EnergyReport {
    pub fn total_uj(&self) -> f64 {
        self.compute_uj + self.sram_uj + self.dram_uj
    }
}

/// Estimate the energy of executing `graph` (uses the same cost records
/// as the latency profile, so the two are always consistent).
pub fn estimate(cfg: &NpuConfig, graph: &Graph, em: &EnergyModel) -> EnergyReport {
    let profile = Profile::of(cfg, graph);
    let mut rep = EnergyReport::default();
    for r in &profile.records {
        let c = &r.cost;
        let compute_pj = match c.engine {
            // MPU cycles issue rows*cols MACs each
            Engine::Mpu => c.cycles * cfg.macs_per_cycle() * em.pj_per_mac,
            Engine::Dsp => c.cycles * em.pj_per_dsp_cycle,
            Engine::PluDrain => c.cycles * cfg.plu_elems_per_cycle * em.pj_per_plu_elem,
            Engine::Dma => 0.0,
        };
        rep.compute_uj += compute_pj / 1e6;
        rep.sram_uj += c.sram_bytes * em.pj_per_sram_byte / 1e6;
        rep.dram_uj += c.dram_bytes * em.pj_per_dram_byte / 1e6;
    }
    rep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{npu_series2, presets};
    use crate::passes::{cumba::CumbaPass, reduba::RedubaPass, Pass};

    #[test]
    fn xamba_passes_also_save_energy() {
        // the paper's "improved memory efficiency" claim, in joules:
        // CumBA+ReduBA must cut energy (less DSP time, less re-streaming)
        let cfg = npu_series2();
        let em = EnergyModel::default();
        let g = crate::models::build_block(&presets::block130m_mamba2(), 4);
        let base = estimate(&cfg, &g, &em);
        let opt = estimate(&cfg, &RedubaPass.apply(&CumbaPass.apply(&g)), &em);
        // the big tensors still stream once either way; the saving is the
        // DSP re-streaming amplification (~18% of total energy here)
        assert!(
            opt.total_uj() < base.total_uj() * 0.9,
            "base {:.1} uJ vs opt {:.1} uJ",
            base.total_uj(),
            opt.total_uj()
        );
        // and the saving is memory-dominated (the paper's argument)
        assert!(base.dram_uj > base.compute_uj);
    }

    #[test]
    fn energy_is_additive_and_positive() {
        let cfg = npu_series2();
        let em = EnergyModel::default();
        let g = crate::models::build_block(&presets::block130m_mamba(), 4);
        let r = estimate(&cfg, &g, &em);
        assert!(r.compute_uj > 0.0 && r.sram_uj > 0.0);
        assert!((r.total_uj() - (r.compute_uj + r.sram_uj + r.dram_uj)).abs() < 1e-9);
    }
}
