//! Zero-Value Compression model (paper Fig 3, after Rhu et al. HPCA'18).
//!
//! ZVC stores only the non-zero elements of a tensor plus a 1-bit-per-
//! element sparsity bitmap. The NPU datapath uses the same bitmap to skip
//! zero-operand MACs ("two-sided sparsity acceleration"). CumBA's lower-
//! triangular mask is ~50 % zeros, so both effects kick in; Mamba weights
//! have negligible sparsity (paper §2.1), so they see no benefit.

/// Compressed byte size of an f32 buffer with `nnz` non-zeros out of `n`.
pub fn compressed_bytes(n: usize, nnz: usize) -> usize {
    debug_assert!(nnz <= n);
    nnz * 4 + n.div_ceil(8)
}

/// Density (non-zero fraction) of an n x n lower-triangular mask
/// (diagonal included): (n+1) / (2n).
pub fn tril_density(n: usize) -> f64 {
    if n == 0 {
        return 0.0;
    }
    (n + 1) as f64 / (2 * n) as f64
}

/// Count non-zeros of an f32 slice (exact-zero test, matching hardware).
pub fn count_nnz(data: &[f32]) -> usize {
    data.iter().filter(|&&v| v != 0.0).count()
}

/// Compression ratio (compressed / raw); > 1 means ZVC would inflate.
pub fn ratio(n: usize, nnz: usize) -> f64 {
    compressed_bytes(n, nnz) as f64 / (n * 4) as f64
}

/// ZVC round trip: compress to (values, bitmap), decompress back.
/// The simulator only needs the *sizes*, but the codec is implemented and
/// tested so the model is grounded in a real encoding.
pub fn compress(data: &[f32]) -> (Vec<f32>, Vec<u8>) {
    let mut values = Vec::with_capacity(count_nnz(data));
    let mut bitmap = vec![0u8; data.len().div_ceil(8)];
    for (i, &v) in data.iter().enumerate() {
        if v != 0.0 {
            values.push(v);
            bitmap[i / 8] |= 1 << (i % 8);
        }
    }
    (values, bitmap)
}

/// Inverse of `compress`; `n` is the uncompressed length.
pub fn decompress(values: &[f32], bitmap: &[u8], n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n];
    let mut vi = 0;
    for (i, o) in out.iter_mut().enumerate() {
        if bitmap[i / 8] & (1 << (i % 8)) != 0 {
            *o = values[vi];
            vi += 1;
        }
    }
    debug_assert_eq!(vi, values.len());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    #[test]
    fn tril_density_converges_to_half() {
        assert!((tril_density(1) - 1.0).abs() < 1e-12);
        assert!((tril_density(256) - 257.0 / 512.0).abs() < 1e-12);
        assert!(tril_density(4096) < 0.51);
    }

    #[test]
    fn round_trip_random_sparse() {
        let mut rng = Prng::new(5);
        let data: Vec<f32> = (0..1000)
            .map(|_| if rng.uniform() < 0.5 { 0.0 } else { rng.normal() })
            .collect();
        let (v, bm) = compress(&data);
        assert_eq!(decompress(&v, &bm, data.len()), data);
        assert_eq!(v.len(), count_nnz(&data));
    }

    #[test]
    fn mask_compression_halves_storage() {
        // 256x256 tril mask: paper's CumBA mask
        let n = 256;
        let mut mask = vec![0.0f32; n * n];
        for i in 0..n {
            for j in 0..=i {
                mask[i * n + j] = 1.0;
            }
        }
        let nnz = count_nnz(&mask);
        let r = ratio(n * n, nnz);
        assert!(r < 0.56, "ratio {r}"); // ~0.50 payload + 1/128 bitmap
    }

    #[test]
    fn dense_data_inflates_slightly() {
        // all-nonzero: bitmap is pure overhead
        let r = ratio(1000, 1000);
        assert!(r > 1.0 && r < 1.04);
    }

    #[test]
    fn all_zero_compresses_to_bitmap() {
        let (v, bm) = compress(&[0.0; 64]);
        assert!(v.is_empty());
        assert_eq!(bm.len(), 8);
        assert_eq!(compressed_bytes(64, 0), 8);
    }
}
