//! Graph-level profiling: run the cost model over a graph and aggregate.
//!
//! This produces the paper's measurement artifacts: per-op latency
//! breakdowns (Fig 1, Fig 4(b)(c)) and end-to-end latencies (Fig 4(a)).

use std::collections::BTreeMap;

use crate::config::NpuConfig;
use crate::exec::Schedule;
use crate::graph::Graph;
use crate::util::Table;

use super::cost::{node_cost, Engine, NodeCost};

/// Cost of one executed node.
#[derive(Clone, Debug)]
pub struct NodeRecord {
    pub id: usize,
    pub name: String,
    pub op: &'static str,
    pub cost: NodeCost,
}

/// Aggregated per-op-kind latency (a Fig-1-style row).
#[derive(Clone, Debug, Default)]
pub struct OpAggregate {
    pub count: usize,
    pub total_ns: f64,
    pub comp_ns: f64,
    pub mem_ns: f64,
    pub dram_bytes: f64,
    pub sram_bytes: f64,
}

/// Full profile of a graph on the simulated NPU.
#[derive(Clone, Debug)]
pub struct Profile {
    pub graph_name: String,
    pub records: Vec<NodeRecord>,
    pub total_ns: f64,
}

impl Profile {
    /// Profile all live nodes of `graph` (sequential NPU execution).
    /// Uses the same live-set schedule the planned executor compiles
    /// from (`exec::Schedule`), so cost model and executor price/run an
    /// identical node set.
    pub fn of(cfg: &NpuConfig, graph: &Graph) -> Self {
        let schedule = Schedule::of(graph);
        let mut records = Vec::new();
        let mut total = 0.0;
        for &id in &schedule.order {
            let node = graph.node(id);
            let cost = node_cost(cfg, graph, node);
            total += cost.total_ns;
            records.push(NodeRecord {
                id: node.id,
                name: node.name.clone(),
                op: node.op.census_name(),
                cost,
            });
        }
        Self { graph_name: graph.name.clone(), records, total_ns: total }
    }

    /// Aggregate by operator kind, descending by share.
    pub fn by_op(&self) -> Vec<(&'static str, OpAggregate)> {
        let mut map: BTreeMap<&'static str, OpAggregate> = BTreeMap::new();
        for r in &self.records {
            if r.cost.total_ns == 0.0 {
                continue;
            }
            let e = map.entry(r.op).or_default();
            e.count += 1;
            e.total_ns += r.cost.total_ns;
            e.comp_ns += r.cost.comp_ns;
            e.mem_ns += r.cost.mem_ns;
            e.dram_bytes += r.cost.dram_bytes;
            e.sram_bytes += r.cost.sram_bytes;
        }
        let mut v: Vec<_> = map.into_iter().collect();
        v.sort_by(|a, b| b.1.total_ns.partial_cmp(&a.1.total_ns).unwrap());
        v
    }

    /// Aggregate by engine.
    pub fn by_engine(&self) -> Vec<(&'static str, f64)> {
        let mut map: BTreeMap<&'static str, f64> = BTreeMap::new();
        for r in &self.records {
            *map.entry(r.cost.engine.name()).or_default() += r.cost.total_ns;
        }
        let mut v: Vec<_> = map.into_iter().collect();
        v.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        v
    }

    /// Total latency attributed to one op kind.
    pub fn op_ns(&self, op: &str) -> f64 {
        self.records
            .iter()
            .filter(|r| r.op == op)
            .map(|r| r.cost.total_ns)
            .sum()
    }

    /// Share (0..1) of total latency attributed to `op`.
    pub fn op_share(&self, op: &str) -> f64 {
        if self.total_ns == 0.0 {
            0.0
        } else {
            self.op_ns(op) / self.total_ns
        }
    }

    /// Total DSP time share — "how sequential is this graph".
    pub fn engine_share(&self, engine: Engine) -> f64 {
        let t: f64 = self
            .records
            .iter()
            .filter(|r| r.cost.engine == engine)
            .map(|r| r.cost.total_ns)
            .sum();
        if self.total_ns == 0.0 {
            0.0
        } else {
            t / self.total_ns
        }
    }

    /// Fig-1-style breakdown table (op, count, time, share, traffic).
    pub fn breakdown_table(&self) -> Table {
        let mut t = Table::new(&["op", "count", "time", "share", "DRAM", "SRAM"])
            .with_title(&format!(
                "{} — total {}",
                self.graph_name,
                crate::util::table::fmt_ns(self.total_ns)
            ));
        for (op, agg) in self.by_op() {
            t.row(&[
                op.to_string(),
                agg.count.to_string(),
                crate::util::table::fmt_ns(agg.total_ns),
                format!("{:5.1}%", 100.0 * agg.total_ns / self.total_ns),
                format!("{:.1} KiB", agg.dram_bytes / 1024.0),
                format!("{:.1} KiB", agg.sram_bytes / 1024.0),
            ]);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::npu_series2;
    use crate::graph::Graph;

    fn sample_graph() -> Graph {
        let mut g = Graph::new("sample");
        let x = g.input("x", vec![256, 256]);
        let w = g.input("w", vec![256, 64]);
        let m = g.matmul(x, w, "proj");
        let a = g.silu(m, "act");
        let c = g.cumsum(a, 0, "cs");
        g.output(c);
        g
    }

    #[test]
    fn profile_sums_node_latencies() {
        let p = Profile::of(&npu_series2(), &sample_graph());
        let sum: f64 = p.records.iter().map(|r| r.cost.total_ns).sum();
        assert!((p.total_ns - sum).abs() < 1e-9);
        assert!(p.total_ns > 0.0);
    }

    #[test]
    fn shares_sum_to_one() {
        let p = Profile::of(&npu_series2(), &sample_graph());
        let s: f64 = p.by_op().iter().map(|(_, a)| a.total_ns).sum();
        assert!((s - p.total_ns).abs() / p.total_ns < 1e-9);
        let share_sum = p.op_share("MatMul") + p.op_share("Swish") + p.op_share("CumSum");
        assert!((share_sum - 1.0).abs() < 1e-9);
    }

    #[test]
    fn dead_nodes_not_profiled() {
        let mut g = sample_graph();
        let dead_in = g.input("dead", vec![1024, 1024]);
        g.softplus(dead_in, "dead_act");
        let p = Profile::of(&npu_series2(), &g);
        assert!(p.records.iter().all(|r| r.name != "dead_act"));
    }

    #[test]
    fn breakdown_table_renders() {
        let p = Profile::of(&npu_series2(), &sample_graph());
        let s = p.breakdown_table().render();
        assert!(s.contains("CumSum"));
        assert!(s.contains("%"));
    }
}
