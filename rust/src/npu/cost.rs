//! Per-node engine assignment and cost functions of the NPU model.
//!
//! The model follows the paper's architecture split (Fig 2(a)): MatMul-
//! like ops run on the high-frequency MPU MAC array; sequential /
//! transcendental ops run on the DSP; PLU nodes ride the MPU drain path.
//! Each node gets a compute time and a memory time (SRAM + DRAM streams);
//! the node latency is `max(compute, memory)` — DMA overlaps compute.

use crate::config::NpuConfig;
use crate::graph::op::{ConstKind, Op, UnKind};
use crate::graph::{numel, Graph, Node};

use super::zvc;

/// Execution engine a node is scheduled on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    /// MAC-array matrix unit.
    Mpu,
    /// Vector DSP (sequential ops, activations).
    Dsp,
    /// Piecewise-linear unit in the MPU drain path.
    PluDrain,
    /// Pure data movement (gathers, layout).
    Dma,
}

impl Engine {
    pub fn name(&self) -> &'static str {
        match self {
            Engine::Mpu => "MPU",
            Engine::Dsp => "DSP",
            Engine::PluDrain => "PLU",
            Engine::Dma => "DMA",
        }
    }
}

/// Cost record for one node.
#[derive(Clone, Debug)]
pub struct NodeCost {
    pub engine: Engine,
    pub cycles: f64,
    pub comp_ns: f64,
    pub sram_bytes: f64,
    pub dram_bytes: f64,
    pub mem_ns: f64,
    pub total_ns: f64,
    /// MPU utilization (MatMul only): useful-MACs / issued-MACs.
    pub mpu_util: f64,
}

impl NodeCost {
    fn zero(engine: Engine) -> Self {
        Self {
            engine,
            cycles: 0.0,
            comp_ns: 0.0,
            sram_bytes: 0.0,
            dram_bytes: 0.0,
            mem_ns: 0.0,
            total_ns: 0.0,
            mpu_util: 0.0,
        }
    }
}

const F32B: f64 = 4.0;

/// Bytes of a shape in f32.
fn bytes(shape: &[usize]) -> f64 {
    numel(shape) as f64 * F32B
}

/// Where a tensor streams from: true = DRAM, false = SRAM.
fn input_from_dram(cfg: &NpuConfig, graph: &Graph, id: usize) -> bool {
    let node = graph.node(id);
    match node.op {
        // weights / activations entering the NPU: DRAM first touch
        Op::Input { .. } | Op::Const { .. } => true,
        // intermediates stay in SRAM when they fit
        _ => bytes(&node.shape) > (cfg.sram_kib * 1024) as f64,
    }
}

/// Effective streamed bytes of an input, accounting for ZVC on masks and
/// FP16 weight storage (graph inputs / constants are converted weights).
fn input_bytes(cfg: &NpuConfig, graph: &Graph, id: usize) -> f64 {
    let node = graph.node(id);
    let stored = numel(&node.shape) as f64 * cfg.weight_bytes;
    match node.op {
        Op::Const { kind } => match kind {
            ConstKind::TrilMask if cfg.zvc_enabled => {
                let n = numel(&node.shape);
                let nnz = node
                    .value
                    .as_ref()
                    .map(|t| zvc::count_nnz(t.as_f32()))
                    .unwrap_or(n / 2);
                zvc::compressed_bytes(n, nnz) as f64 * cfg.weight_bytes / F32B
            }
            // the ones vector is read once and reused by every output
            // column (ReduBA's reuse argument): count it once.
            ConstKind::OnesMask => stored,
            _ => stored,
        },
        Op::Input { .. } => stored,
        _ => bytes(&node.shape),
    }
}

/// Density of a MatMul operand if it is a skippable mask constant.
fn operand_skip_density(cfg: &NpuConfig, graph: &Graph, id: usize) -> f64 {
    if !cfg.sparsity_skip_enabled {
        return 1.0;
    }
    let node = graph.node(id);
    if let Op::Const { kind: ConstKind::TrilMask } = node.op {
        let n = numel(&node.shape);
        let nnz = node
            .value
            .as_ref()
            .map(|t| zvc::count_nnz(t.as_f32()))
            .unwrap_or(n / 2);
        return nnz as f64 / n as f64;
    }
    1.0
}

/// Compute the cost of one node in its graph context.
pub fn node_cost(cfg: &NpuConfig, graph: &Graph, node: &Node) -> NodeCost {
    let mpu_ns_per_cycle = 1.0 / cfg.mpu_freq_ghz;
    let dsp_ns_per_cycle = 1.0 / cfg.dsp_freq_ghz;
    let out_elems = numel(&node.shape) as f64;

    // default memory traffic: stream every input + write the output
    let mut sram = 0.0f64;
    let mut dram = 0.0f64;
    let mut add_io = |cfgr: &NpuConfig, g: &Graph, ids: &[usize], out: &[usize]| {
        for &i in ids {
            let b = input_bytes(cfgr, g, i);
            if input_from_dram(cfgr, g, i) {
                dram += b;
            } else {
                sram += b;
            }
        }
        let ob = bytes(out);
        if ob > (cfgr.sram_kib * 1024) as f64 {
            dram += ob;
        } else {
            sram += ob;
        }
    };

    let mut cost = match &node.op {
        Op::Input { .. } | Op::Const { .. } => return NodeCost::zero(Engine::Dma),

        Op::MatMul => {
            let a = graph.shape(node.inputs[0]);
            let b = graph.shape(node.inputs[1]);
            let m = a[a.len() - 2];
            let k = a[a.len() - 1];
            let n = b[b.len() - 1];
            let batch = numel(&node.shape) / (m * n);
            let tiles_m = m.div_ceil(cfg.mpu_rows);
            let tiles_n = n.div_ceil(cfg.mpu_cols);
            let density = operand_skip_density(cfg, graph, node.inputs[0])
                * operand_skip_density(cfg, graph, node.inputs[1]);
            let cycles =
                (batch * tiles_m * tiles_n * k) as f64 * density;
            let useful = (batch * m * n * k) as f64 * density;
            let issued = (batch * tiles_m * cfg.mpu_rows * tiles_n * cfg.mpu_cols * k)
                as f64;
            let mut c = NodeCost::zero(Engine::Mpu);
            c.cycles = cycles;
            c.comp_ns = cycles * mpu_ns_per_cycle;
            c.mpu_util = useful / issued.max(1.0);
            add_io(cfg, graph, &node.inputs, &node.shape);
            c
        }

        Op::Conv1dCausal { k } => {
            // depthwise: C independent K-tap dots, mapped across the array
            let t = node.shape[0];
            let c_ch = node.shape[1];
            let lanes = cfg.mpu_rows * cfg.mpu_cols;
            let cycles = (t * *k) as f64 * (c_ch as f64 / lanes as f64).ceil();
            let mut c = NodeCost::zero(Engine::Mpu);
            c.cycles = cycles;
            c.comp_ns = cycles * mpu_ns_per_cycle;
            add_io(cfg, graph, &node.inputs, &node.shape);
            c
        }

        Op::Binary(_) => {
            // data-parallel elementwise: runs on the MPU's vector datapath
            // (one lane per PE), full memory bandwidth
            let cycles = out_elems / cfg.macs_per_cycle();
            let mut c = NodeCost::zero(Engine::Mpu);
            c.cycles = cycles;
            c.comp_ns = cycles * mpu_ns_per_cycle;
            add_io(cfg, graph, &node.inputs, &node.shape);
            c
        }

        Op::Unary(kind) => {
            // composite transcendentals run near-SCALAR on the DSP (no
            // lane parallelism — the Fig-1 bottleneck); simple
            // transcendentals vectorize across lanes; trivial unaries ride
            // the MPU vector path like Binary.
            let mut dispatch_ns = 0.0;
            let (engine, cycles) = match kind {
                UnKind::SiLU | UnKind::Softplus => {
                    dispatch_ns = cfg.dsp_dispatch_us * 1e3;
                    (Engine::Dsp, out_elems * cfg.dsp_act_cycles_per_elem)
                }
                UnKind::Sigmoid | UnKind::Tanh => {
                    dispatch_ns = cfg.dsp_dispatch_us * 1e3;
                    (Engine::Dsp, out_elems * cfg.dsp_act_cycles_per_elem / 2.0)
                }
                UnKind::Exp | UnKind::Log | UnKind::Sqrt | UnKind::Recip => (
                    Engine::Dsp,
                    out_elems * cfg.dsp_exp_cycles_per_elem / cfg.dsp_lanes as f64,
                ),
                UnKind::Neg | UnKind::Abs | UnKind::Relu => {
                    (Engine::Mpu, out_elems / cfg.macs_per_cycle())
                }
            };
            let mut c = NodeCost::zero(engine);
            c.cycles = cycles;
            c.comp_ns = dispatch_ns
                + cycles
                    * if engine == Engine::Dsp { dsp_ns_per_cycle } else { mpu_ns_per_cycle };
            add_io(cfg, graph, &node.inputs, &node.shape);
            c
        }

        Op::Plu { .. } => {
            // Drain-path PLU: when the producer is an MPU op the multiply-
            // add happens as the tile drains — no extra memory traffic
            // ("vertical fusion", Fig 2(e)). Standalone PLU still streams.
            let producer_is_mpu = matches!(
                graph.node(node.inputs[0]).op,
                Op::MatMul | Op::Conv1dCausal { .. }
            );
            let cycles = out_elems / cfg.plu_elems_per_cycle;
            let mut c = NodeCost::zero(Engine::PluDrain);
            c.cycles = cycles;
            c.comp_ns = cycles * mpu_ns_per_cycle;
            if !producer_is_mpu {
                add_io(cfg, graph, &node.inputs, &node.shape);
            }
            c
        }

        Op::CumSum { axis } => {
            // paper §2.1: m sequential steps of an n-wide vector adder,
            // with an RF<->SRAM round trip per row for large tensors
            let shape = &node.shape;
            let rows = shape[*axis] as f64;
            let inner: usize = shape[*axis + 1..].iter().product();
            let outer: usize = shape[..*axis].iter().product();
            let width_steps = (inner.max(1) as f64 / cfg.dsp_lanes as f64).ceil();
            let spill = if (inner.max(1) as f64) * F32B > (cfg.dsp_rf_kib * 1024) as f64
            {
                2.0 // chunked rows spill twice as often
            } else {
                1.0
            };
            let cycles = outer as f64
                * rows
                * (width_steps * cfg.dsp_row_cycles
                    + cfg.cumsum_row_overhead * spill);
            let mut c = NodeCost::zero(Engine::Dsp);
            c.cycles = cycles;
            c.comp_ns = cycles * dsp_ns_per_cycle;
            add_io(cfg, graph, &node.inputs, &node.shape);
            // chunked sequential processing re-streams operands
            sram *= cfg.dsp_seq_mem_amplification;
            dram *= cfg.dsp_seq_mem_amplification;
            c
        }

        Op::ReduceSum { axis } => {
            let in_shape = graph.shape(node.inputs[0]);
            let rows = in_shape[*axis] as f64;
            let inner: usize = in_shape[*axis + 1..].iter().product();
            let outer: usize = in_shape[..*axis].iter().product();
            let cycles = if inner == 1 {
                // innermost-axis reduction: lanes vectorize along the
                // reduction itself (tree reduce per output)
                outer as f64
                    * ((rows / cfg.dsp_lanes as f64).ceil() * cfg.dsp_row_cycles
                        + cfg.reducesum_row_overhead)
            } else {
                let width_steps = (inner as f64 / cfg.dsp_lanes as f64).ceil();
                outer as f64
                    * rows
                    * (width_steps * cfg.dsp_row_cycles + cfg.reducesum_row_overhead)
            };
            let mut c = NodeCost::zero(Engine::Dsp);
            c.cycles = cycles;
            c.comp_ns = cycles * dsp_ns_per_cycle;
            add_io(cfg, graph, &node.inputs, &node.shape);
            c
        }

        Op::RmsNorm { .. } => {
            // two reduction+scale passes on the vector datapath
            let cycles = out_elems * 3.0 / cfg.macs_per_cycle();
            let mut c = NodeCost::zero(Engine::Mpu);
            c.cycles = cycles;
            c.comp_ns = cycles * mpu_ns_per_cycle;
            add_io(cfg, graph, &node.inputs, &node.shape);
            c
        }

        Op::Softmax { .. } => {
            let cycles = out_elems
                * (2.0 * cfg.dsp_ew_cycles_per_elem + cfg.dsp_exp_cycles_per_elem)
                / cfg.dsp_lanes as f64;
            let mut c = NodeCost::zero(Engine::Dsp);
            c.cycles = cycles;
            c.comp_ns = cycles * dsp_ns_per_cycle;
            add_io(cfg, graph, &node.inputs, &node.shape);
            c
        }

        Op::Gather => {
            // pure data movement: read rows + write output
            let c = NodeCost::zero(Engine::Dma);
            let ob = bytes(&node.shape);
            sram += 2.0 * ob;
            c
        }

        Op::Quantize { .. } | Op::Dequantize => {
            // precision conversion rides the MPU vector datapath like
            // plain elementwise arithmetic (drain-path cast on real NPUs)
            let cycles = out_elems / cfg.macs_per_cycle();
            let mut c = NodeCost::zero(Engine::Mpu);
            c.cycles = cycles;
            c.comp_ns = cycles * mpu_ns_per_cycle;
            add_io(cfg, graph, &node.inputs, &node.shape);
            c
        }

        // layout ops fold into DMA descriptors: free compute, and their
        // traffic is attributed to the consuming op
        Op::Slice { .. }
        | Op::Concat { .. }
        | Op::Reshape { .. }
        | Op::Transpose { .. }
        | Op::Broadcast { .. } => return NodeCost::zero(Engine::Dma),
    };

    cost.sram_bytes = sram;
    cost.dram_bytes = dram;
    // bytes / (GB/s) = ns. DSP-resident sequential ops stream through the
    // DSP's private DMA path instead of the MPU's wide buses.
    // only CumSum is row-dependent (can't prefetch past the carried row);
    // ReduceSum streams linearly and keeps the normal memory path
    let seq_dsp = matches!(node.op, Op::CumSum { .. });
    cost.mem_ns = if seq_dsp {
        (sram + dram) / cfg.dsp_mem_gbps
    } else {
        sram / cfg.sram_gbps + dram / cfg.dram_gbps
    };
    cost.total_ns = cost.comp_ns.max(cost.mem_ns);
    cost
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{npu_series2, npu_unit};
    use crate::graph::Graph;

    fn cost_of(g: &Graph, id: usize, cfg: &NpuConfig) -> NodeCost {
        node_cost(cfg, g, g.node(id))
    }

    #[test]
    fn unit_npu_matmul_cycles_are_mnk() {
        let cfg = npu_unit();
        let mut g = Graph::new("t");
        let a = g.input("a", vec![3, 5]);
        let b = g.input("b", vec![5, 7]);
        let m = g.matmul(a, b, "m");
        let c = cost_of(&g, m, &cfg);
        assert_eq!(c.engine, Engine::Mpu);
        assert!((c.cycles - (3 * 7 * 5) as f64).abs() < 1e-9);
        assert!((c.mpu_util - 1.0).abs() < 1e-9);
    }

    #[test]
    fn cumsum_cycles_scale_with_rows() {
        let cfg = npu_unit();
        let mut g = Graph::new("t");
        let x = g.input("x", vec![8, 4]);
        let cs = g.cumsum(x, 0, "cs");
        let c = cost_of(&g, cs, &cfg);
        assert_eq!(c.engine, Engine::Dsp);
        // 8 rows x ceil(4/1) lane steps
        assert!((c.cycles - 32.0).abs() < 1e-9);
    }

    #[test]
    fn cumba_mask_matmul_skips_zero_macs() {
        let mut with = npu_series2();
        with.sparsity_skip_enabled = true;
        let mut without = with.clone();
        without.sparsity_skip_enabled = false;
        let mut g = Graph::new("t");
        let x = g.input("x", vec![256, 64]);
        let mask = g.const_tril("m", 256);
        let mm = g.matmul(mask, x, "cumba");
        let c_with = cost_of(&g, mm, &with);
        let c_without = cost_of(&g, mm, &without);
        let expected = zvc::tril_density(256);
        assert!((c_with.cycles / c_without.cycles - expected).abs() < 1e-6);
    }

    #[test]
    fn zvc_compresses_mask_traffic() {
        let mut on = npu_series2();
        on.zvc_enabled = true;
        let mut off = on.clone();
        off.zvc_enabled = false;
        let mut g = Graph::new("t");
        let x = g.input("x", vec![128, 32]);
        let mask = g.const_tril("m", 128);
        let mm = g.matmul(mask, x, "cumba");
        let c_on = cost_of(&g, mm, &on);
        let c_off = cost_of(&g, mm, &off);
        // mask nnz ~0.504: ZVC nearly halves its stored bytes
        let saved = c_off.dram_bytes - c_on.dram_bytes;
        let mask_stored = 128.0 * 128.0 * on.weight_bytes;
        assert!(saved > mask_stored * 0.35, "saved {saved}");
        assert!(c_on.dram_bytes < c_off.dram_bytes * 0.85);
    }

    #[test]
    fn activations_cost_more_than_adds() {
        let cfg = npu_series2();
        let mut g = Graph::new("t");
        let x = g.input("x", vec![64, 64]);
        let sw = g.silu(x, "sw");
        let ad = g.add(x, x, "ad");
        let c_sw = cost_of(&g, sw, &cfg);
        let c_ad = cost_of(&g, ad, &cfg);
        assert!(c_sw.cycles > 10.0 * c_ad.cycles);
    }

    #[test]
    fn plu_fused_into_mpu_producer_is_nearly_free() {
        let cfg = npu_series2();
        let mut g = Graph::new("t");
        let a = g.input("a", vec![64, 64]);
        let b = g.input("b", vec![64, 64]);
        let m = g.matmul(a, b, "m");
        let table = std::sync::Arc::new(crate::plu::default_silu());
        let p = g.plu(m, table.clone(), UnKind::SiLU, "plu");
        let c_p = cost_of(&g, p, &cfg);
        assert_eq!(c_p.engine, Engine::PluDrain);
        assert_eq!(c_p.mem_ns, 0.0); // vertical fusion: no extra traffic
        // standalone PLU (producer on DSP) pays memory
        let s = g.silu(a, "act");
        let p2 = g.plu(s, table, UnKind::SiLU, "plu2");
        assert!(cost_of(&g, p2, &cfg).mem_ns > 0.0);
    }

    #[test]
    fn layout_ops_are_free() {
        let cfg = npu_series2();
        let mut g = Graph::new("t");
        let x = g.input("x", vec![4, 4]);
        let r = g.reshape(x, vec![16], "r");
        let c = cost_of(&g, r, &cfg);
        assert_eq!(c.total_ns, 0.0);
    }
}
