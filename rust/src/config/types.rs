//! Typed configuration structs (NPU cost model, model shapes, serving).
//!
//! Every struct can be loaded from the TOML-subset format via `from_doc`
//! with a section prefix, so one file configures the whole stack:
//!
//! ```toml
//! [npu]
//! mpu_rows = 32
//! [serve]
//! model = "tiny-mamba"
//! variant = "xamba"
//! ```

use super::toml::TomlDoc;

/// Largest number of drafted tokens per speculative decode step
/// (`--speculate K`). The verify window is K+1 (drafts + the bonus
/// token), so this bounds the compiled verify-graph family per bucket.
pub const SPECULATE_CAP: usize = 8;

/// Cost-model parameters of the simulated NPU (DESIGN.md §1: substitution
/// for the Intel Core Ultra Series 2 NPU). Defaults are calibrated so the
/// *baseline* Mamba/Mamba-2 profiles reproduce the bottleneck shares of
/// paper Fig 1; see `config::presets::npu_series2`.
#[derive(Clone, Debug, PartialEq)]
pub struct NpuConfig {
    /// MPU MAC array rows (output-stationary, Fig 2(a)).
    pub mpu_rows: usize,
    /// MPU MAC array columns.
    pub mpu_cols: usize,
    /// MPU clock, GHz ("high-frequency MAC array").
    pub mpu_freq_ghz: f64,
    /// DSP vector lanes (the paper's "n-width vector adder").
    pub dsp_lanes: usize,
    /// DSP clock, GHz.
    pub dsp_freq_ghz: f64,
    /// DSP cycles per element for composite transcendental activations
    /// (Swish = sigmoid+mul, Softplus = exp+log — the paper's Fig-1
    /// bottlenecks; evaluated by polynomial iteration on the DSP).
    pub dsp_act_cycles_per_elem: f64,
    /// DSP cycles per element for single transcendentals (Exp, Log, ...).
    pub dsp_exp_cycles_per_elem: f64,
    /// DSP cycles per element for plain elementwise arithmetic.
    pub dsp_ew_cycles_per_elem: f64,
    /// Fixed DSP kernel-dispatch overhead per composite-activation op,
    /// microseconds (firmware round trip to launch a Swish/Softplus DSP
    /// routine; ActiBA's drain-path fusion eliminates it entirely).
    pub dsp_dispatch_us: f64,
    /// DSP cycles per vector-row step of CumSum/ReduceSum (adder latency).
    pub dsp_row_cycles: f64,
    /// Fixed per-row overhead cycles of CumSum: the sequential dependence
    /// forces a register-file <-> SRAM round trip per row (paper §2.1:
    /// "processed in smaller chunks ... frequent SRAM transfers").
    pub cumsum_row_overhead: f64,
    /// Per-row overhead of ReduceSum (accumulate-only: cheaper).
    pub reducesum_row_overhead: f64,
    /// Memory-traffic amplification of DSP-sequential ops (CumSum /
    /// ReduceSum): chunked processing re-reads operands instead of
    /// streaming them once like the MPU's tiled walk (paper §2.1).
    pub dsp_seq_mem_amplification: f64,
    /// Elements the PLU can drain per MPU cycle (C-LUT multiply-add lives
    /// in the drain path, so it is effectively free unless it exceeds
    /// drain bandwidth).
    pub plu_elems_per_cycle: f64,
    /// On-chip SRAM capacity in KiB (spills beyond this go to DRAM).
    pub sram_kib: usize,
    /// SRAM bandwidth, GiB/s.
    pub sram_gbps: f64,
    /// DRAM (LPDDR) bandwidth, GiB/s.
    pub dram_gbps: f64,
    /// Effective stream bandwidth of the DSP's private DMA path, GiB/s —
    /// sequential ops cannot use the MPU's wide buses (paper §2.1).
    pub dsp_mem_gbps: f64,
    /// Bytes per weight element as stored (the paper compresses weights
    /// to FP16 during conversion): scales Input/Const streaming traffic.
    pub weight_bytes: f64,
    /// DSP register-file capacity in KiB; CumSum chunks that exceed it
    /// round-trip through SRAM every chunk (paper §2.1).
    pub dsp_rf_kib: usize,
    /// Zero-value compression on constant masks (paper Fig 3).
    pub zvc_enabled: bool,
    /// Sparsity-bitmap compute skip in the MPU datapath.
    pub sparsity_skip_enabled: bool,
}

impl Default for NpuConfig {
    fn default() -> Self {
        super::presets::npu_series2()
    }
}

impl NpuConfig {
    /// Load from a parsed TOML doc; missing keys keep defaults.
    pub fn from_doc(doc: &TomlDoc, section: &str) -> Self {
        let d = Self::default();
        let k = |name: &str| format!("{section}.{name}");
        Self {
            mpu_rows: doc.i64_or(&k("mpu_rows"), d.mpu_rows as i64) as usize,
            mpu_cols: doc.i64_or(&k("mpu_cols"), d.mpu_cols as i64) as usize,
            mpu_freq_ghz: doc.f64_or(&k("mpu_freq_ghz"), d.mpu_freq_ghz),
            dsp_lanes: doc.i64_or(&k("dsp_lanes"), d.dsp_lanes as i64) as usize,
            dsp_freq_ghz: doc.f64_or(&k("dsp_freq_ghz"), d.dsp_freq_ghz),
            dsp_act_cycles_per_elem: doc
                .f64_or(&k("dsp_act_cycles_per_elem"), d.dsp_act_cycles_per_elem),
            dsp_exp_cycles_per_elem: doc
                .f64_or(&k("dsp_exp_cycles_per_elem"), d.dsp_exp_cycles_per_elem),
            dsp_ew_cycles_per_elem: doc
                .f64_or(&k("dsp_ew_cycles_per_elem"), d.dsp_ew_cycles_per_elem),
            dsp_dispatch_us: doc.f64_or(&k("dsp_dispatch_us"), d.dsp_dispatch_us),
            dsp_row_cycles: doc.f64_or(&k("dsp_row_cycles"), d.dsp_row_cycles),
            cumsum_row_overhead: doc
                .f64_or(&k("cumsum_row_overhead"), d.cumsum_row_overhead),
            reducesum_row_overhead: doc
                .f64_or(&k("reducesum_row_overhead"), d.reducesum_row_overhead),
            dsp_seq_mem_amplification: doc.f64_or(
                &k("dsp_seq_mem_amplification"),
                d.dsp_seq_mem_amplification,
            ),
            plu_elems_per_cycle: doc
                .f64_or(&k("plu_elems_per_cycle"), d.plu_elems_per_cycle),
            sram_kib: doc.i64_or(&k("sram_kib"), d.sram_kib as i64) as usize,
            sram_gbps: doc.f64_or(&k("sram_gbps"), d.sram_gbps),
            dram_gbps: doc.f64_or(&k("dram_gbps"), d.dram_gbps),
            dsp_mem_gbps: doc.f64_or(&k("dsp_mem_gbps"), d.dsp_mem_gbps),
            weight_bytes: doc.f64_or(&k("weight_bytes"), d.weight_bytes),
            dsp_rf_kib: doc.i64_or(&k("dsp_rf_kib"), d.dsp_rf_kib as i64) as usize,
            zvc_enabled: doc.bool_or(&k("zvc_enabled"), d.zvc_enabled),
            sparsity_skip_enabled: doc
                .bool_or(&k("sparsity_skip_enabled"), d.sparsity_skip_enabled),
        }
    }

    /// MACs per MPU cycle.
    pub fn macs_per_cycle(&self) -> f64 {
        (self.mpu_rows * self.mpu_cols) as f64
    }
}

/// Model architecture shapes — rust mirror of `python/compile/configs.py`
/// (the AOT manifest carries the same numbers; `models::` builds IR graphs
/// from this struct).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelShape {
    pub name: String,
    /// "mamba" | "mamba2"
    pub arch: String,
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub d_state: usize,
    pub d_conv: usize,
    pub expand: usize,
    /// mamba-1 only (0 = d_model/16)
    pub dt_rank: usize,
    /// mamba-2 only
    pub headdim: usize,
    pub chunk: usize,
}

impl ModelShape {
    pub fn d_inner(&self) -> usize {
        self.expand * self.d_model
    }

    pub fn resolved_dt_rank(&self) -> usize {
        if self.dt_rank == 0 {
            (self.d_model / 16).max(1)
        } else {
            self.dt_rank
        }
    }

    pub fn n_heads(&self) -> usize {
        debug_assert_eq!(self.d_inner() % self.headdim, 0);
        self.d_inner() / self.headdim
    }

    /// Channels through the causal conv (mamba2 convs x, B, C together).
    pub fn conv_dim(&self) -> usize {
        if self.arch == "mamba2" {
            self.d_inner() + 2 * self.d_state
        } else {
            self.d_inner()
        }
    }
}

/// Serving configuration for the coordinator.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeConfig {
    /// Model backend: "planned" (IR graphs on the planned executor — no
    /// artifacts required) | "pjrt" (AOT executables).
    pub backend: String,
    /// Directory holding the AOT artifacts (manifest.json etc.).
    pub artifacts_dir: String,
    /// Model preset name from the manifest (e.g. "tiny-mamba").
    pub model: String,
    /// "baseline" | "xamba".
    pub variant: String,
    /// Serving dtype of the planned backend: "f32" (default) | "f16"
    /// (half-precision weights + compute, f32 accumulation) | "i8"
    /// (per-tensor symmetric int8 projection GEMMs, dynamic activation
    /// scales). The pjrt backend executes f32 artifacts only.
    pub dtype: String,
    /// Decode batch buckets available as compiled executables.
    pub decode_buckets: Vec<usize>,
    /// Batched-prefill admission buckets of the planned backend: how
    /// many concurrently admitted, equal-length requests one prefill
    /// graph call may cover. Graphs compile lazily per (bucket,
    /// length-class); bucket 1 is always available.
    pub prefill_buckets: Vec<usize>,
    /// Work-stealing decode chunk size of the planned backend's pool
    /// (sequences per stolen chunk; must be a compiled decode bucket to
    /// take effect). 0 = auto: the largest compiled bucket that fits
    /// ceil(bucket / workers).
    pub steal_chunk: usize,
    /// Admission queue capacity (requests beyond this are rejected).
    pub queue_cap: usize,
    /// Maximum resident sequences (state-cache slots).
    pub max_slots: usize,
    /// Default generation length when a request does not specify one.
    pub default_max_new_tokens: usize,
    /// Microseconds the batcher waits to fill a larger bucket.
    pub batch_wait_us: u64,
    /// Prefill window of the planned backend (PJRT takes it from the
    /// manifest).
    pub prefill_window: usize,
    /// Execution-pool worker threads for the planned backend; 0 = auto
    /// (available parallelism, capped at 4), 1 = serial.
    pub workers: usize,
    /// Explicit weights file for the planned backend; "" = use
    /// `{artifacts_dir}/weights_{model}.bin` if present, else a
    /// deterministic random init.
    pub weights_path: String,
    /// Prefix-cache byte budget in MiB (planned backend, f32/f16):
    /// finished sequences' recurrent states are retained keyed by their
    /// token prefix, so a follow-up turn resumes decode-exactly and only
    /// prefills its new suffix. 0 disables cross-request state reuse.
    pub prefix_cache_mb: usize,
    /// Streaming-prefill chunk size in tokens (planned backend): prompts
    /// longer than the compiled window run as fixed-size chunk graphs
    /// with bounded arena memory, checkpointing state at chunk
    /// boundaries. 0 = off (long prompts truncate to the window).
    pub prefill_chunk: usize,
    /// Token budget of the continuous-batching scheduler: the sum over
    /// resident sequences of (encoded prompt tokens + max_new_tokens
    /// headroom) never exceeds this. Requests whose own cost exceeds it
    /// are rejected at admission. 0 = unbounded (slots are the only
    /// residency limit).
    pub max_batch_total_tokens: usize,
    /// Admission policy knob: while sequences are decoding, a prefill
    /// round is deferred until `waiting >= ratio * active` — larger
    /// values favor decode latency of the running batch over TTFT of
    /// the queue. 0.0 = admit eagerly whenever slots and budget allow.
    pub waiting_served_ratio: f64,
    /// Default per-request deadline in milliseconds from arrival
    /// (requests may override via `GenParams::deadline_ms`); past it the
    /// scheduler finishes the request as DeadlineExceeded and frees its
    /// budget. 0 = no deadline.
    pub deadline_ms: u64,
    /// Replica engines behind the serving router. 1 = a single engine
    /// (no router); >1 starts `coordinator::router` with least-loaded
    /// routing and session affinity across this many engines.
    pub replicas: usize,
    /// Per-replica serving dtype overrides for heterogeneous fleets
    /// (e.g. ["f32", "f16", "i8", "i8"]); replicas beyond the list keep
    /// the base `dtype`. Empty = homogeneous fleet.
    pub replica_dtypes: Vec<String>,
    /// Per-replica worker-thread overrides; replicas beyond the list
    /// keep the base `workers`. Empty = homogeneous fleet.
    pub replica_workers: Vec<usize>,
    /// Router dispatch cap: requests outstanding (dispatched, not yet
    /// resolved) per replica. Keep at or below `queue_cap` so balanced
    /// dispatch alone can never trip a replica's own Overloaded
    /// backpressure. 0 = uncapped.
    pub replica_inflight: usize,
    /// Speculative-decoding draft length K (planned backend, greedy
    /// requests): a prompt-lookup proposer drafts up to K tokens per
    /// decode step and one batched verify graph scores the whole window.
    /// Kept signed so a negative CLI/TOML value reaches `validate` with
    /// an actionable message instead of wrapping. 0 = off (default).
    pub speculate: i64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            backend: "planned".into(),
            artifacts_dir: "artifacts".into(),
            model: "tiny-mamba".into(),
            variant: "xamba".into(),
            dtype: "f32".into(),
            decode_buckets: vec![1, 2, 4, 8],
            prefill_buckets: vec![1, 2, 4, 8],
            steal_chunk: 0,
            queue_cap: 256,
            max_slots: 64,
            default_max_new_tokens: 48,
            batch_wait_us: 200,
            prefill_window: 32,
            workers: 0,
            weights_path: String::new(),
            prefix_cache_mb: 32,
            prefill_chunk: 0,
            max_batch_total_tokens: 0,
            waiting_served_ratio: 0.0,
            deadline_ms: 0,
            replicas: 1,
            replica_dtypes: Vec::new(),
            replica_workers: Vec::new(),
            replica_inflight: 32,
            speculate: 0,
        }
    }
}

impl ServeConfig {
    /// Check the code-path-selecting knobs up front and return one clear,
    /// actionable message instead of letting an unknown string panic (or
    /// surface a confusing downstream error) inside the engine thread.
    /// `coordinator::start_backend` calls this before spawning anything.
    pub fn validate(&self) -> Result<(), String> {
        let planned = match self.backend.as_str() {
            "" | "planned" => true,
            "pjrt" => false,
            other => {
                return Err(format!(
                    "unknown serve backend {other:?} (want \"planned\" or \"pjrt\")"
                ))
            }
        };
        // only the planned backend draws models from the preset table;
        // pjrt resolves the name against the artifacts manifest, which can
        // carry custom converted shapes
        if planned && super::presets::model_by_name(&self.model).is_none() {
            return Err(format!(
                "unknown serve model {:?} (known presets: {})",
                self.model,
                super::presets::MODEL_NAMES.join(", ")
            ));
        }
        match self.variant.as_str() {
            "" | "baseline" | "xamba" => {}
            other => {
                return Err(format!(
                    "unknown serve variant {other:?} (want \"baseline\" or \"xamba\")"
                ))
            }
        }
        match crate::graph::tensor::DType::parse_serve(&self.dtype) {
            None => {
                let supported = crate::graph::tensor::SERVE_DTYPES
                    .iter()
                    .map(|d| d.name())
                    .collect::<Vec<_>>()
                    .join(", ");
                return Err(format!(
                    "unknown serve dtype {:?} (supported dtypes: {supported})",
                    self.dtype
                ));
            }
            Some(crate::graph::tensor::DType::F32) => {}
            Some(d) if !planned => {
                return Err(format!(
                    "serve dtype {:?} requires the planned backend \
                     (the pjrt backend executes f32 AOT artifacts)",
                    d.name()
                ));
            }
            Some(_) => {}
        }
        if self.decode_buckets.is_empty() || self.decode_buckets.contains(&0) {
            return Err(
                "serve decode_buckets must be a non-empty list of positive batch sizes"
                    .into(),
            );
        }
        if self.prefill_buckets.is_empty() || self.prefill_buckets.contains(&0) {
            return Err(
                "serve prefill_buckets must be a non-empty list of positive batch sizes"
                    .into(),
            );
        }
        if !self.waiting_served_ratio.is_finite() || self.waiting_served_ratio < 0.0 {
            return Err(format!(
                "serve waiting_served_ratio must be a finite ratio >= 0 \
                 (got {})",
                self.waiting_served_ratio
            ));
        }
        if self.replicas == 0 {
            return Err("serve replicas must be >= 1 (1 = no router)".into());
        }
        if !self.replica_dtypes.is_empty() && self.replica_dtypes.len() != self.replicas
        {
            return Err(format!(
                "serve replica_dtypes lists {} dtypes for {} replicas \
                 (give one per replica, or none for a homogeneous fleet)",
                self.replica_dtypes.len(),
                self.replicas
            ));
        }
        for dt in &self.replica_dtypes {
            match crate::graph::tensor::DType::parse_serve(dt) {
                None => {
                    let supported = crate::graph::tensor::SERVE_DTYPES
                        .iter()
                        .map(|d| d.name())
                        .collect::<Vec<_>>()
                        .join(", ");
                    return Err(format!(
                        "unknown replica dtype {dt:?} \
                         (supported dtypes: {supported})"
                    ));
                }
                Some(crate::graph::tensor::DType::F32) => {}
                Some(d) if !planned => {
                    return Err(format!(
                        "replica dtype {:?} requires the planned backend \
                         (the pjrt backend executes f32 AOT artifacts)",
                        d.name()
                    ));
                }
                Some(_) => {}
            }
        }
        if !self.replica_workers.is_empty()
            && self.replica_workers.len() != self.replicas
        {
            return Err(format!(
                "serve replica_workers lists {} counts for {} replicas \
                 (give one per replica, or none for a homogeneous fleet)",
                self.replica_workers.len(),
                self.replicas
            ));
        }
        if self.speculate < 0 {
            return Err(format!(
                "serve speculate must be >= 0 drafted tokens per step \
                 (got {}; 0 disables speculative decoding)",
                self.speculate
            ));
        }
        if self.speculate > SPECULATE_CAP as i64 {
            return Err(format!(
                "serve speculate {} exceeds the cap of {SPECULATE_CAP} \
                 drafted tokens per step (longer windows compile large \
                 verify graphs for little acceptance gain)",
                self.speculate
            ));
        }
        if self.speculate > 0 && !planned {
            return Err(format!(
                "serve speculate {} requires the planned backend \
                 (the pjrt backend has no verify executables; \
                 use --backend planned or --speculate 0)",
                self.speculate
            ));
        }
        Ok(())
    }

    pub fn from_doc(doc: &TomlDoc, section: &str) -> Self {
        let d = Self::default();
        let k = |name: &str| format!("{section}.{name}");
        let bucket_list = |name: &str, default: &[usize]| -> Vec<usize> {
            doc.get(&k(name))
                .and_then(|v| match v {
                    super::toml::TomlValue::Arr(a) => Some(
                        a.iter()
                            .filter_map(|x| x.as_i64())
                            .map(|x| x as usize)
                            .collect::<Vec<_>>(),
                    ),
                    _ => None,
                })
                .unwrap_or_else(|| default.to_vec())
        };
        // per-replica override lists accept either a TOML array or the
        // CLI's comma-separated string form ("f32,f16,i8")
        let str_list = |name: &str| -> Vec<String> {
            match doc.get(&k(name)) {
                Some(super::toml::TomlValue::Arr(a)) => a
                    .iter()
                    .filter_map(|x| x.as_str())
                    .map(|s| s.to_string())
                    .collect(),
                Some(super::toml::TomlValue::Str(s)) => s
                    .split(',')
                    .map(|p| p.trim())
                    .filter(|p| !p.is_empty())
                    .map(|p| p.to_string())
                    .collect(),
                _ => Vec::new(),
            }
        };
        let count_list = |name: &str| -> Vec<usize> {
            match doc.get(&k(name)) {
                Some(super::toml::TomlValue::Arr(a)) => a
                    .iter()
                    .filter_map(|x| x.as_i64())
                    .map(|x| x.max(0) as usize)
                    .collect(),
                Some(super::toml::TomlValue::Str(s)) => s
                    .split(',')
                    .filter_map(|p| p.trim().parse::<usize>().ok())
                    .collect(),
                _ => Vec::new(),
            }
        };
        Self {
            backend: doc.str_or(&k("backend"), &d.backend).into(),
            artifacts_dir: doc.str_or(&k("artifacts_dir"), &d.artifacts_dir).into(),
            model: doc.str_or(&k("model"), &d.model).into(),
            variant: doc.str_or(&k("variant"), &d.variant).into(),
            dtype: doc.str_or(&k("dtype"), &d.dtype).into(),
            decode_buckets: bucket_list("decode_buckets", &d.decode_buckets),
            prefill_buckets: bucket_list("prefill_buckets", &d.prefill_buckets),
            steal_chunk: doc.i64_or(&k("steal_chunk"), d.steal_chunk as i64).max(0)
                as usize,
            queue_cap: doc.i64_or(&k("queue_cap"), d.queue_cap as i64) as usize,
            max_slots: doc.i64_or(&k("max_slots"), d.max_slots as i64) as usize,
            default_max_new_tokens: doc
                .i64_or(&k("default_max_new_tokens"), d.default_max_new_tokens as i64)
                as usize,
            batch_wait_us: doc.i64_or(&k("batch_wait_us"), d.batch_wait_us as i64)
                as u64,
            // clamp: a negative value would wrap through `as usize` into
            // an enormous thread count / unroll length
            prefill_window: doc
                .i64_or(&k("prefill_window"), d.prefill_window as i64)
                .max(1) as usize,
            workers: doc.i64_or(&k("workers"), d.workers as i64).max(0) as usize,
            weights_path: doc.str_or(&k("weights_path"), &d.weights_path).into(),
            prefix_cache_mb: doc
                .i64_or(&k("prefix_cache_mb"), d.prefix_cache_mb as i64)
                .max(0) as usize,
            prefill_chunk: doc
                .i64_or(&k("prefill_chunk"), d.prefill_chunk as i64)
                .max(0) as usize,
            max_batch_total_tokens: doc
                .i64_or(&k("max_batch_total_tokens"), d.max_batch_total_tokens as i64)
                .max(0) as usize,
            waiting_served_ratio: doc
                .f64_or(&k("waiting_served_ratio"), d.waiting_served_ratio)
                .max(0.0),
            deadline_ms: doc.i64_or(&k("deadline_ms"), d.deadline_ms as i64).max(0)
                as u64,
            // a zero/negative replica count would make the router
            // unstartable: clamp to the single-engine minimum
            replicas: doc.i64_or(&k("replicas"), d.replicas as i64).max(1) as usize,
            replica_dtypes: str_list("replica_dtypes"),
            replica_workers: count_list("replica_workers"),
            replica_inflight: doc
                .i64_or(&k("replica_inflight"), d.replica_inflight as i64)
                .max(0) as usize,
            // deliberately NOT clamped: validate() owns the error message
            speculate: doc.i64_or(&k("speculate"), d.speculate),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn npu_from_doc_overrides_and_defaults() {
        let doc = TomlDoc::parse("[npu]\nmpu_rows = 16\nzvc_enabled = false\n").unwrap();
        let c = NpuConfig::from_doc(&doc, "npu");
        assert_eq!(c.mpu_rows, 16);
        assert!(!c.zvc_enabled);
        // untouched key keeps preset default
        assert_eq!(c.dsp_lanes, NpuConfig::default().dsp_lanes);
    }

    #[test]
    fn serve_from_doc_parses_buckets() {
        let doc = TomlDoc::parse(
            "[serve]\nmodel = \"tiny-mamba2\"\ndecode_buckets = [1, 4]\n\
             prefill_buckets = [1, 2]\nsteal_chunk = 2\n",
        )
        .unwrap();
        let c = ServeConfig::from_doc(&doc, "serve");
        assert_eq!(c.model, "tiny-mamba2");
        assert_eq!(c.decode_buckets, vec![1, 4]);
        assert_eq!(c.prefill_buckets, vec![1, 2]);
        assert_eq!(c.steal_chunk, 2);
        // untouched backend knobs keep defaults
        assert_eq!(c.backend, "planned");
        assert_eq!(c.workers, 0);
    }

    #[test]
    fn serve_from_doc_defaults_admission_knobs() {
        let doc = TomlDoc::parse("[serve]\nsteal_chunk = -3\n").unwrap();
        let c = ServeConfig::from_doc(&doc, "serve");
        assert_eq!(c.prefill_buckets, ServeConfig::default().prefill_buckets);
        assert_eq!(c.steal_chunk, 0, "negative steal_chunk must clamp to auto");
    }

    #[test]
    fn serve_from_doc_parses_state_reuse_knobs() {
        let doc =
            TomlDoc::parse("[serve]\nprefix_cache_mb = 8\nprefill_chunk = 64\n").unwrap();
        let c = ServeConfig::from_doc(&doc, "serve");
        assert_eq!(c.prefix_cache_mb, 8);
        assert_eq!(c.prefill_chunk, 64);
        // defaults: cache on, chunking off; negatives clamp to off
        let d = ServeConfig::default();
        assert_eq!(d.prefix_cache_mb, 32);
        assert_eq!(d.prefill_chunk, 0);
        let doc =
            TomlDoc::parse("[serve]\nprefix_cache_mb = -1\nprefill_chunk = -2\n").unwrap();
        let c = ServeConfig::from_doc(&doc, "serve");
        assert_eq!(c.prefix_cache_mb, 0);
        assert_eq!(c.prefill_chunk, 0);
    }

    #[test]
    fn serve_from_doc_parses_scheduler_knobs() {
        let doc = TomlDoc::parse(
            "[serve]\nmax_batch_total_tokens = 4096\n\
             waiting_served_ratio = 1.5\ndeadline_ms = 250\n",
        )
        .unwrap();
        let c = ServeConfig::from_doc(&doc, "serve");
        assert_eq!(c.max_batch_total_tokens, 4096);
        assert!((c.waiting_served_ratio - 1.5).abs() < 1e-12);
        assert_eq!(c.deadline_ms, 250);
        // defaults: unbounded budget, eager admission, no deadline
        let d = ServeConfig::default();
        assert_eq!(d.max_batch_total_tokens, 0);
        assert_eq!(d.waiting_served_ratio, 0.0);
        assert_eq!(d.deadline_ms, 0);
        // negatives clamp instead of wrapping
        let doc = TomlDoc::parse(
            "[serve]\nmax_batch_total_tokens = -1\n\
             waiting_served_ratio = -0.5\ndeadline_ms = -7\n",
        )
        .unwrap();
        let c = ServeConfig::from_doc(&doc, "serve");
        assert_eq!(c.max_batch_total_tokens, 0);
        assert_eq!(c.waiting_served_ratio, 0.0);
        assert_eq!(c.deadline_ms, 0);
    }

    #[test]
    fn serve_from_doc_parses_replica_knobs() {
        // TOML array form
        let doc = TomlDoc::parse(
            "[serve]\nreplicas = 3\nreplica_dtypes = [\"f32\", \"f16\", \"i8\"]\n\
             replica_workers = [1, 2, 2]\nreplica_inflight = 8\n",
        )
        .unwrap();
        let c = ServeConfig::from_doc(&doc, "serve");
        assert_eq!(c.replicas, 3);
        assert_eq!(c.replica_dtypes, vec!["f32", "f16", "i8"]);
        assert_eq!(c.replica_workers, vec![1, 2, 2]);
        assert_eq!(c.replica_inflight, 8);
        assert_eq!(c.validate(), Ok(()));
        // comma-separated string form (the CLI flag shape)
        let doc = TomlDoc::parse(
            "[serve]\nreplicas = 2\nreplica_dtypes = \"f16, i8\"\n\
             replica_workers = \"1,2\"\n",
        )
        .unwrap();
        let c = ServeConfig::from_doc(&doc, "serve");
        assert_eq!(c.replica_dtypes, vec!["f16", "i8"]);
        assert_eq!(c.replica_workers, vec![1, 2]);
        // defaults: single engine, homogeneous, capped dispatch
        let d = ServeConfig::default();
        assert_eq!(d.replicas, 1);
        assert!(d.replica_dtypes.is_empty() && d.replica_workers.is_empty());
        assert_eq!(d.replica_inflight, 32);
        // negatives clamp instead of wrapping
        let doc =
            TomlDoc::parse("[serve]\nreplicas = -2\nreplica_inflight = -1\n").unwrap();
        let c = ServeConfig::from_doc(&doc, "serve");
        assert_eq!(c.replicas, 1);
        assert_eq!(c.replica_inflight, 0);
    }

    #[test]
    fn validate_flags_bad_replica_knobs() {
        let bad = ServeConfig { replicas: 0, ..Default::default() };
        assert!(bad.validate().unwrap_err().contains("replicas"));

        // list length must match the fleet size
        let bad = ServeConfig {
            replicas: 3,
            replica_dtypes: vec!["f32".into(), "f16".into()],
            ..Default::default()
        };
        let msg = bad.validate().unwrap_err();
        assert!(msg.contains("replica_dtypes") && msg.contains("3"), "{msg}");
        let bad = ServeConfig {
            replicas: 2,
            replica_workers: vec![1, 2, 4],
            ..Default::default()
        };
        assert!(bad.validate().unwrap_err().contains("replica_workers"));

        // each per-replica dtype is validated like the base dtype
        let bad = ServeConfig {
            replicas: 2,
            replica_dtypes: vec!["f32".into(), "bf16".into()],
            ..Default::default()
        };
        let msg = bad.validate().unwrap_err();
        assert!(msg.contains("bf16") && msg.contains("f16"), "{msg}");
        // quantized replicas need the planned backend
        let bad = ServeConfig {
            backend: "pjrt".into(),
            replicas: 2,
            replica_dtypes: vec!["f32".into(), "i8".into()],
            ..Default::default()
        };
        assert!(bad.validate().unwrap_err().contains("planned backend"));

        let ok = ServeConfig {
            replicas: 4,
            replica_dtypes: vec!["f32".into(), "f16".into(), "i8".into(), "i8".into()],
            replica_workers: vec![2, 2, 1, 1],
            ..Default::default()
        };
        assert_eq!(ok.validate(), Ok(()));
    }

    #[test]
    fn serve_from_doc_parses_speculate() {
        let doc = TomlDoc::parse("[serve]\nspeculate = 4\n").unwrap();
        let c = ServeConfig::from_doc(&doc, "serve");
        assert_eq!(c.speculate, 4);
        assert_eq!(c.validate(), Ok(()));
        // default is off
        assert_eq!(ServeConfig::default().speculate, 0);
        // negatives are preserved so validate can name them
        let doc = TomlDoc::parse("[serve]\nspeculate = -2\n").unwrap();
        assert_eq!(ServeConfig::from_doc(&doc, "serve").speculate, -2);
    }

    #[test]
    fn validate_flags_bad_speculate() {
        let bad = ServeConfig { speculate: -1, ..Default::default() };
        let msg = bad.validate().unwrap_err();
        assert!(msg.contains("speculate") && msg.contains(">= 0"), "{msg}");
        assert!(msg.contains("-1"), "{msg}");

        let bad = ServeConfig {
            speculate: SPECULATE_CAP as i64 + 1,
            ..Default::default()
        };
        let msg = bad.validate().unwrap_err();
        assert!(msg.contains("speculate") && msg.contains("cap"), "{msg}");
        assert!(msg.contains(&SPECULATE_CAP.to_string()), "{msg}");

        // speculation needs the planned backend's verify graphs
        let bad = ServeConfig {
            backend: "pjrt".into(),
            speculate: 2,
            ..Default::default()
        };
        let msg = bad.validate().unwrap_err();
        assert!(msg.contains("planned backend"), "{msg}");
        assert!(msg.contains("--speculate 0"), "{msg}");

        // every in-range K validates on the planned backend
        for k in 0..=SPECULATE_CAP as i64 {
            let ok = ServeConfig { speculate: k, ..Default::default() };
            assert_eq!(ok.validate(), Ok(()), "speculate {k} must validate");
        }
    }

    #[test]
    fn validate_flags_bad_waiting_served_ratio() {
        for bad in [f64::NAN, f64::INFINITY, -1.0] {
            let c = ServeConfig { waiting_served_ratio: bad, ..Default::default() };
            let msg = c.validate().unwrap_err();
            assert!(msg.contains("waiting_served_ratio"), "{msg}");
        }
        let ok = ServeConfig { waiting_served_ratio: 2.0, ..Default::default() };
        assert_eq!(ok.validate(), Ok(()));
    }

    #[test]
    fn serve_from_doc_clamps_negative_backend_knobs() {
        let doc = TomlDoc::parse("[serve]\nworkers = -1\nprefill_window = -3\n").unwrap();
        let c = ServeConfig::from_doc(&doc, "serve");
        assert_eq!(c.workers, 0, "negative workers must not wrap");
        assert_eq!(c.prefill_window, 1, "negative window must not wrap");
    }

    #[test]
    fn validate_flags_unknown_backend_model_and_variant() {
        let ok = ServeConfig::default();
        assert_eq!(ok.validate(), Ok(()));

        let bad = ServeConfig { backend: "cuda".into(), ..Default::default() };
        let msg = bad.validate().unwrap_err();
        assert!(msg.contains("unknown serve backend") && msg.contains("cuda"), "{msg}");
        assert!(msg.contains("planned") && msg.contains("pjrt"), "{msg}");

        let bad = ServeConfig { model: "gpt-5".into(), ..Default::default() };
        let msg = bad.validate().unwrap_err();
        assert!(msg.contains("unknown serve model") && msg.contains("gpt-5"), "{msg}");
        // actionable: the message lists what WOULD work
        assert!(msg.contains("tiny-mamba2"), "{msg}");
        // ...but pjrt models come from the artifacts manifest, not the
        // preset table — a non-preset name must pass config validation
        let pjrt = ServeConfig {
            backend: "pjrt".into(),
            model: "custom-converted".into(),
            ..Default::default()
        };
        assert_eq!(pjrt.validate(), Ok(()));

        let bad = ServeConfig { variant: "int8".into(), ..Default::default() };
        let msg = bad.validate().unwrap_err();
        assert!(msg.contains("unknown serve variant") && msg.contains("int8"), "{msg}");

        // dtype validation: unknown strings name every supported dtype
        for wrong in ["int8", "fp16", "bf16", "f64"] {
            let bad = ServeConfig { dtype: wrong.into(), ..Default::default() };
            let msg = bad.validate().unwrap_err();
            assert!(msg.contains("unknown serve dtype") && msg.contains(wrong), "{msg}");
            assert!(
                msg.contains("f32") && msg.contains("f16") && msg.contains("i8"),
                "actionable list missing: {msg}"
            );
        }
        for ok_dtype in ["", "f32", "f16", "i8"] {
            let c = ServeConfig { dtype: ok_dtype.into(), ..Default::default() };
            assert_eq!(c.validate(), Ok(()), "dtype {ok_dtype:?} must validate");
        }
        // quantized serving is a planned-backend feature
        let bad = ServeConfig {
            backend: "pjrt".into(),
            dtype: "i8".into(),
            ..Default::default()
        };
        let msg = bad.validate().unwrap_err();
        assert!(msg.contains("planned backend"), "{msg}");
        let ok_pjrt = ServeConfig {
            backend: "pjrt".into(),
            dtype: "f32".into(),
            ..Default::default()
        };
        assert_eq!(ok_pjrt.validate(), Ok(()));

        let bad = ServeConfig { decode_buckets: vec![], ..Default::default() };
        assert!(bad.validate().unwrap_err().contains("decode_buckets"));
        let bad = ServeConfig { decode_buckets: vec![1, 0], ..Default::default() };
        assert!(bad.validate().unwrap_err().contains("decode_buckets"));
        let bad = ServeConfig { prefill_buckets: vec![], ..Default::default() };
        assert!(bad.validate().unwrap_err().contains("prefill_buckets"));
        let bad = ServeConfig { prefill_buckets: vec![0, 2], ..Default::default() };
        assert!(bad.validate().unwrap_err().contains("prefill_buckets"));
    }

    #[test]
    fn model_shape_derived_dims() {
        let m = ModelShape {
            name: "t".into(),
            arch: "mamba2".into(),
            vocab_size: 256,
            d_model: 128,
            n_layers: 2,
            d_state: 32,
            d_conv: 4,
            expand: 2,
            dt_rank: 0,
            headdim: 32,
            chunk: 16,
        };
        assert_eq!(m.d_inner(), 256);
        assert_eq!(m.n_heads(), 8);
        assert_eq!(m.conv_dim(), 256 + 64);
        assert_eq!(m.resolved_dt_rank(), 8);
    }
}
