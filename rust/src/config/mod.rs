//! Configuration system: TOML-subset parsing, typed configs, presets.
//!
//! One TOML file can configure the whole stack (`[npu]`, `[serve]`
//! sections); every struct also has calibrated defaults so the binaries
//! run with zero configuration.

pub mod presets;
pub mod toml;
pub mod types;

pub use presets::{model_by_name, npu_series2, npu_unit};
pub use toml::{TomlDoc, TomlValue};
pub use types::{ModelShape, NpuConfig, ServeConfig, SPECULATE_CAP};

/// Load a TOML config file; `None` path yields an empty doc (defaults).
pub fn load(path: Option<&str>) -> Result<TomlDoc, String> {
    match path {
        None => Ok(TomlDoc::default()),
        Some(p) => {
            let src = std::fs::read_to_string(p)
                .map_err(|e| format!("read {p}: {e}"))?;
            TomlDoc::parse(&src).map_err(|e| format!("{p}: {e}"))
        }
    }
}
