//! Minimal TOML-subset parser for runtime configuration files.
//!
//! Supports what our configs use: `[section]` and `[section.sub]` headers,
//! `key = value` with string / integer / float / boolean / array-of-scalar
//! values, `#` comments, and blank lines. Unsupported TOML (multi-line
//! strings, inline tables, dates) is rejected with a line-numbered error.

use std::collections::BTreeMap;

/// A scalar or array config value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// A parsed document: `section.key -> value` (root keys use section "").
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TomlDoc {
    map: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    pub fn parse(src: &str) -> Result<TomlDoc, String> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| err(lineno, "unterminated section header"))?
                    .trim();
                if name.is_empty() {
                    return Err(err(lineno, "empty section name"));
                }
                section = name.to_string();
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| err(lineno, "expected key = value"))?;
            let key = line[..eq].trim();
            if key.is_empty() {
                return Err(err(lineno, "empty key"));
            }
            let val = parse_value(line[eq + 1..].trim())
                .map_err(|e| err(lineno, &e))?;
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            if doc.map.insert(full.clone(), val).is_some() {
                return Err(err(lineno, &format!("duplicate key {full}")));
            }
        }
        Ok(doc)
    }

    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.map.get(key)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(|v| v.as_str()).unwrap_or(default)
    }

    pub fn i64_or(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(|v| v.as_i64()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(|s| s.as_str())
    }
}

fn err(lineno: usize, msg: &str) -> String {
    format!("line {}: {msg}", lineno + 1)
}

/// Strip a `#` comment, respecting quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        if inner.contains('"') {
            return Err("embedded quote in string".into());
        }
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?
            .trim();
        if inner.is_empty() {
            return Ok(TomlValue::Arr(vec![]));
        }
        let items = inner
            .split(',')
            .map(|p| parse_value(p.trim()))
            .collect::<Result<Vec<_>, _>>()?;
        return Ok(TomlValue::Arr(items));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.replace('_', "").parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let doc = TomlDoc::parse(
            "top = 1\n[npu]\nmpu_rows = 32 # comment\nfreq = 1.4\nname = \"series2\"\nzvc = true\n",
        )
        .unwrap();
        assert_eq!(doc.i64_or("top", 0), 1);
        assert_eq!(doc.i64_or("npu.mpu_rows", 0), 32);
        assert!((doc.f64_or("npu.freq", 0.0) - 1.4).abs() < 1e-12);
        assert_eq!(doc.str_or("npu.name", ""), "series2");
        assert!(doc.bool_or("npu.zvc", false));
    }

    #[test]
    fn parses_arrays() {
        let doc = TomlDoc::parse("buckets = [1, 2, 4, 8]\n").unwrap();
        match doc.get("buckets").unwrap() {
            TomlValue::Arr(a) => {
                assert_eq!(a.len(), 4);
                assert_eq!(a[3].as_i64(), Some(8));
            }
            _ => panic!("not an array"),
        }
    }

    #[test]
    fn comment_inside_string_kept() {
        let doc = TomlDoc::parse("k = \"a#b\"\n").unwrap();
        assert_eq!(doc.str_or("k", ""), "a#b");
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = TomlDoc::parse("ok = 1\nbroken line\n").unwrap_err();
        assert!(e.starts_with("line 2:"), "{e}");
        assert!(TomlDoc::parse("[unterminated\n").is_err());
        assert!(TomlDoc::parse("a = 1\na = 2\n").is_err());
    }

    #[test]
    fn int_with_underscores() {
        let doc = TomlDoc::parse("n = 1_000_000\n").unwrap();
        assert_eq!(doc.i64_or("n", 0), 1_000_000);
    }
}
