//! Calibrated presets: the simulated NPU and the paper's model shapes.

use super::types::{ModelShape, NpuConfig};

/// Cost model calibrated against the Intel® Core™ Ultra Series 2 NPU
/// ("NPU 4", 256V): public figures put it at ~48 TOPS INT8 across 6 neural
/// compute engines. We model the slice one block's execution sees:
/// a 32x32 output-stationary MAC array at 1.4 GHz plus a 32-lane DSP at
/// 0.7 GHz. The *shape-critical* constants — DSP activation cost and
/// CumSum row cost — are calibrated so the baseline Mamba / Mamba-2
/// profiles reproduce Fig 1's bottleneck shares (activations dominant for
/// Mamba-1; CumSum >50 % for Mamba-2); everything downstream (Fig 4
/// speedups) is then *predicted*, not fitted. See EXPERIMENTS.md §Calibration.
pub fn npu_series2() -> NpuConfig {
    NpuConfig {
        mpu_rows: 32,
        mpu_cols: 32,
        mpu_freq_ghz: 1.4,
        dsp_lanes: 32,
        dsp_freq_ghz: 0.7,
        // Composite transcendentals (Swish, Softplus) execute near-
        // scalar on the DSP (no lane parallelism: polynomial + range
        // reduction per element). 10 cycles/element reproduces Fig 1's
        // Mamba-1 activation dominance and Fig 4(c)'s 1.2x / 2.6x.
        dsp_act_cycles_per_elem: 10.0,
        dsp_exp_cycles_per_elem: 4.0,
        dsp_ew_cycles_per_elem: 1.0,
        // firmware dispatch of a DSP activation routine ~30 us; this is
        // what makes tiny decode-time activations still expensive (and
        // what the KPI experiment's 100->260 Tok/s lift removes)
        dsp_dispatch_us: 30.0,
        // One vector-add step per CumSum row (32 lanes wide).
        dsp_row_cycles: 1.0,
        // Sequential row dependence forces an RF<->SRAM round trip per
        // CumSum row; ReduceSum only accumulates, so it is cheaper.
        cumsum_row_overhead: 16.0,
        reducesum_row_overhead: 8.0,
        // row-dependent CumSum chunks re-stream operands ~4x through the
        // DSP's narrow path (8 KiB RF vs KiB-scale rows, paper §2.1)
        dsp_seq_mem_amplification: 4.0,
        plu_elems_per_cycle: 32.0,
        sram_kib: 2048,
        sram_gbps: 256.0,
        // Lunar Lake LPDDR5X-8533 is ~136 GB/s peak; ~96 effective
        dram_gbps: 96.0,
        // the DSP's private DMA path is an order of magnitude narrower
        dsp_mem_gbps: 8.0,
        // OpenVINO conversion compresses weights to FP16 (paper §3)
        weight_bytes: 2.0,
        dsp_rf_kib: 8,
        zvc_enabled: true,
        sparsity_skip_enabled: true,
    }
}

/// A deliberately tiny NPU for tests (1 MAC, 1 lane, 1 KiB SRAM):
/// makes cost-model arithmetic checkable by hand.
pub fn npu_unit() -> NpuConfig {
    NpuConfig {
        mpu_rows: 1,
        mpu_cols: 1,
        mpu_freq_ghz: 1.0,
        dsp_lanes: 1,
        dsp_freq_ghz: 1.0,
        dsp_act_cycles_per_elem: 1.0,
        dsp_exp_cycles_per_elem: 1.0,
        dsp_ew_cycles_per_elem: 1.0,
        dsp_dispatch_us: 0.0,
        dsp_row_cycles: 1.0,
        cumsum_row_overhead: 0.0,
        reducesum_row_overhead: 0.0,
        dsp_seq_mem_amplification: 1.0,
        plu_elems_per_cycle: 1.0,
        sram_kib: 1,
        sram_gbps: 1.0,
        dram_gbps: 1.0,
        dsp_mem_gbps: 1.0,
        weight_bytes: 4.0,
        dsp_rf_kib: 1,
        zvc_enabled: false,
        sparsity_skip_enabled: false,
    }
}

/// Rust mirrors of `python/compile/configs.py` presets.
pub fn tiny_mamba() -> ModelShape {
    ModelShape {
        name: "tiny-mamba".into(),
        arch: "mamba".into(),
        vocab_size: 256,
        d_model: 128,
        n_layers: 2,
        d_state: 16,
        d_conv: 4,
        expand: 2,
        dt_rank: 8,
        headdim: 64,
        chunk: 64,
    }
}

pub fn tiny_mamba2() -> ModelShape {
    ModelShape {
        name: "tiny-mamba2".into(),
        arch: "mamba2".into(),
        vocab_size: 256,
        d_model: 128,
        n_layers: 2,
        d_state: 32,
        d_conv: 4,
        expand: 2,
        dt_rank: 0,
        headdim: 32,
        chunk: 16,
    }
}

/// The exact single-block shapes the paper profiles (mamba-130m-hf).
pub fn block130m_mamba() -> ModelShape {
    ModelShape {
        name: "block130m-mamba".into(),
        arch: "mamba".into(),
        vocab_size: 50280,
        d_model: 768,
        n_layers: 1,
        d_state: 16,
        d_conv: 4,
        expand: 2,
        dt_rank: 48,
        headdim: 64,
        chunk: 64,
    }
}

/// mamba2-130m-hf single-block shape; chunk=256 is what makes CumSum_b a
/// 256x256 CumSum (paper §2.1).
pub fn block130m_mamba2() -> ModelShape {
    ModelShape {
        name: "block130m-mamba2".into(),
        arch: "mamba2".into(),
        vocab_size: 50280,
        d_model: 768,
        n_layers: 1,
        d_state: 128,
        d_conv: 4,
        expand: 2,
        dt_rank: 0,
        headdim: 64,
        chunk: 256,
    }
}

/// Full 24-layer mamba-130m-hf shape (Fig 4(c) / KPI workloads).
pub fn mamba130m() -> ModelShape {
    ModelShape { n_layers: 24, name: "mamba130m".into(), ..block130m_mamba() }
}

/// Full 24-layer mamba2-130m-hf shape.
pub fn mamba2_130m() -> ModelShape {
    ModelShape { n_layers: 24, name: "mamba2-130m".into(), ..block130m_mamba2() }
}

/// Every model preset name [`model_by_name`] resolves — config
/// validation quotes this list in its error messages.
pub const MODEL_NAMES: &[&str] = &[
    "tiny-mamba",
    "tiny-mamba2",
    "block130m-mamba",
    "block130m-mamba2",
    "mamba130m",
    "mamba2-130m",
];

/// Look up a model preset by name.
pub fn model_by_name(name: &str) -> Option<ModelShape> {
    match name {
        "tiny-mamba" => Some(tiny_mamba()),
        "tiny-mamba2" => Some(tiny_mamba2()),
        "block130m-mamba" => Some(block130m_mamba()),
        "block130m-mamba2" => Some(block130m_mamba2()),
        "mamba130m" => Some(mamba130m()),
        "mamba2-130m" => Some(mamba2_130m()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_python_configs() {
        let m2 = block130m_mamba2();
        assert_eq!(m2.d_inner(), 1536);
        assert_eq!(m2.n_heads(), 24);
        assert_eq!(m2.chunk, 256); // the 256x256 CumSum_b
        let m1 = block130m_mamba();
        assert_eq!(m1.resolved_dt_rank(), 48);
        assert_eq!(m1.conv_dim(), 1536);
    }

    #[test]
    fn lookup_by_name() {
        assert!(model_by_name("tiny-mamba").is_some());
        assert!(model_by_name("nope").is_none());
        // the advertised list and the lookup table stay in sync
        for name in MODEL_NAMES {
            assert!(model_by_name(name).is_some(), "{name} not resolvable");
        }
    }

    #[test]
    fn series2_has_parallel_mpu() {
        let c = npu_series2();
        assert!(c.macs_per_cycle() >= 1024.0);
        assert!(c.mpu_freq_ghz > c.dsp_freq_ghz);
    }
}
