//! Model-generic serving builders — the seam `coordinator::PlannedServeModel`
//! selects a model family through.
//!
//! Both Mamba families serve through the same two graph shapes:
//!
//! * a **serve prefill** (tokens → last-position logits + per-layer
//!   decode-ready recurrent state), and
//! * a per-bucket **batched decode step** (tokens (b,) + stacked states →
//!   logits (b, V) + new states).
//!
//! What differs per family is the block math and the *state layout*:
//! Mamba-1 carries `conv (K-1, d_inner)` + `ssm (d_inner, N)`, Mamba-2
//! carries `conv (K-1, d_inner + 2N)` (x, B, C conv together) +
//! the SSD state `ssm (H, P, N)`. [`ServeFamily`] owns both the builder
//! dispatch and the layout so the coordinator never hardcodes either.

use crate::config::ModelShape;
use crate::graph::{Graph, NodeId};

use super::mamba1::Ctx;
use super::params::full_spec;
use super::{mamba1, mamba2};

/// The LM-level scaffolding shared by every serve-prefill graph: embed →
/// per-layer (rmsnorm → block → residual) → final norm → last-position
/// logits, with per-layer `(conv_state, ssm_state)` outputs appended in
/// [`ServeFamily`] order. `block` builds one family-specific block over
/// the normalized input and returns `(block_out, (conv_state, ssm_state))`.
pub(crate) fn lm_serve_scaffold(
    graph_name: &str,
    m: &ModelShape,
    t: usize,
    mut block: impl FnMut(&mut Ctx, usize, NodeId) -> (NodeId, (NodeId, NodeId)),
) -> Graph {
    let spec = full_spec(m);
    let mut ctx = Ctx::new(graph_name, &spec);
    let tokens = ctx.g.input_i32("tokens", vec![t]);
    let emb = ctx.w("emb");
    let mut x = ctx.g.gather(emb, tokens, "embed");
    let mut states: Vec<(NodeId, NodeId)> = Vec::with_capacity(m.n_layers);
    for j in 0..m.n_layers {
        let norm_w = ctx.w(&format!("l{j}.norm_w"));
        let xn = ctx.g.rmsnorm(x, norm_w, &format!("l{j}.norm"));
        let (y, st) = block(&mut ctx, j, xn);
        states.push(st);
        x = ctx.g.add(x, y, &format!("l{j}.residual"));
    }
    let fw = ctx.w("final_norm_w");
    let x = ctx.g.rmsnorm(x, fw, "final_norm");
    let x_last = ctx.g.slice(x, 0, t - 1, 1, "last_pos");
    let emb_t = ctx.g.transpose(emb, vec![1, 0], "lm_head.wT");
    let logits = ctx.g.matmul(x_last, emb_t, "lm_head.mm"); // (1, V)
    ctx.g.output(logits);
    for (cs, ss) in states {
        ctx.g.output(cs);
        ctx.g.output(ss);
    }
    ctx.g
}

/// Resume counterpart of [`lm_serve_scaffold`]: the per-layer recurrent
/// state enters as graph *inputs* instead of starting from zero history,
/// so the engine can run a long prompt as a sequence of fixed-size chunk
/// graphs (bounded arena) and continue a prefix-cache snapshot in O(new
/// tokens). Inputs after the parameters: `tokens` (t,), then per layer
/// `conv_state{j}` / `ssm_state{j}` (the same per-sequence layouts the
/// serve-prefill graphs emit). `block` receives the normalized activation
/// plus that layer's two state inputs and returns `(block_out,
/// (conv_state_out, ssm_state_out))`; outputs match [`lm_serve_scaffold`]
/// exactly, so the coordinator unpacks both with one code path.
pub(crate) fn lm_serve_scaffold_resume(
    graph_name: &str,
    m: &ModelShape,
    t: usize,
    conv_shape: &[usize],
    ssm_shape: &[usize],
    mut block: impl FnMut(&mut Ctx, usize, NodeId, NodeId, NodeId) -> (NodeId, (NodeId, NodeId)),
) -> Graph {
    assert!(t >= 1, "resume prefill needs at least one new token");
    let spec = full_spec(m);
    let mut ctx = Ctx::new(graph_name, &spec);
    let tokens = ctx.g.input_i32("tokens", vec![t]);
    let mut conv_ins: Vec<NodeId> = Vec::with_capacity(m.n_layers);
    let mut ssm_ins: Vec<NodeId> = Vec::with_capacity(m.n_layers);
    for j in 0..m.n_layers {
        conv_ins.push(ctx.g.input(&format!("conv_state{j}"), conv_shape.to_vec()));
        ssm_ins.push(ctx.g.input(&format!("ssm_state{j}"), ssm_shape.to_vec()));
    }
    let emb = ctx.w("emb");
    let mut x = ctx.g.gather(emb, tokens, "embed");
    let mut states: Vec<(NodeId, NodeId)> = Vec::with_capacity(m.n_layers);
    for j in 0..m.n_layers {
        let norm_w = ctx.w(&format!("l{j}.norm_w"));
        let xn = ctx.g.rmsnorm(x, norm_w, &format!("l{j}.norm"));
        let (y, st) = block(&mut ctx, j, xn, conv_ins[j], ssm_ins[j]);
        states.push(st);
        x = ctx.g.add(x, y, &format!("l{j}.residual"));
    }
    let fw = ctx.w("final_norm_w");
    let x = ctx.g.rmsnorm(x, fw, "final_norm");
    let x_last = ctx.g.slice(x, 0, t - 1, 1, "last_pos");
    let emb_t = ctx.g.transpose(emb, vec![1, 0], "lm_head.wT");
    let logits = ctx.g.matmul(x_last, emb_t, "lm_head.mm"); // (1, V)
    ctx.g.output(logits);
    for (cs, ss) in states {
        ctx.g.output(cs);
        ctx.g.output(ss);
    }
    ctx.g
}

/// True-batch counterpart of [`lm_serve_scaffold`]: tokens (b, t) i32 →
/// logits (b, V) + per-layer batch-stacked `(conv, ssm)` states, the
/// same I/O layout as the batched decode graphs.
///
/// Unlike [`lm_serve_scaffold_batched_replicated`], the batch dimension
/// lives INSIDE every node: one (b, t, d) activation per op instead of
/// `b` copies of the single-sequence graph. The kernel layer treats the
/// leading batch dimension independently everywhere this scaffold uses
/// it — matmuls against shared rank-2 weights walk rows, rmsnorm
/// normalizes each (b, t) row on its own, conv / scan / elementwise ops
/// never mix batch rows — so per-sequence results stay **bitwise
/// identical** to the b=1 serve-prefill graph (the invariant the
/// admission scheduler's parity tests pin down) while the step count per
/// admission drops by ~b×. `block` receives the normalized (b, t, d)
/// activation and must return batch-stacked `(conv (b, K-1, C), ssm
/// (b, ...))` states directly.
pub(crate) fn lm_serve_scaffold_batched(
    graph_name: &str,
    m: &ModelShape,
    b: usize,
    t: usize,
    mut block: impl FnMut(&mut Ctx, usize, NodeId) -> (NodeId, (NodeId, NodeId)),
) -> Graph {
    assert!(b >= 1, "prefill bucket must be >= 1");
    let spec = full_spec(m);
    let mut ctx = Ctx::new(graph_name, &spec);
    let tokens = ctx.g.input_i32("tokens", vec![b, t]);
    let emb = ctx.w("emb");
    let tok_flat = ctx.g.reshape(tokens, vec![b * t], "tokens.flat");
    let rows = ctx.g.gather(emb, tok_flat, "embed"); // (b*t, d)
    let mut x = ctx.g.reshape(rows, vec![b, t, m.d_model], "embed.batch");
    let mut states: Vec<(NodeId, NodeId)> = Vec::with_capacity(m.n_layers);
    for j in 0..m.n_layers {
        let norm_w = ctx.w(&format!("l{j}.norm_w"));
        let xn = ctx.g.rmsnorm(x, norm_w, &format!("l{j}.norm"));
        let (y, st) = block(&mut ctx, j, xn);
        states.push(st);
        x = ctx.g.add(x, y, &format!("l{j}.residual"));
    }
    let fw = ctx.w("final_norm_w");
    let x = ctx.g.rmsnorm(x, fw, "final_norm");
    let x_last = ctx.g.slice(x, 1, t - 1, 1, "last_pos"); // (b, 1, d)
    let x_last = ctx.g.reshape(x_last, vec![b, m.d_model], "last_pos.rows");
    let emb_t = ctx.g.transpose(emb, vec![1, 0], "lm_head.wT");
    let logits = ctx.g.matmul(x_last, emb_t, "lm_head.mm"); // (b, V)
    ctx.g.output(logits);
    for (cs, ss) in states {
        ctx.g.output(cs);
        ctx.g.output(ss);
    }
    ctx.g
}

/// Replicated batched scaffold: tokens (b, t) i32 → the same I/O layout
/// as [`lm_serve_scaffold_batched`], but each sequence's computation
/// REPLICATES the single-sequence scaffold node-for-node — same ops over
/// the same values — with only pure layout ops (slice / reshape /
/// concat) doing the batching. This is the fallback for dtypes whose
/// kernels couple co-batched rows (i8's dynamic per-tensor requantize
/// scales would mix sequences inside one (b, t) node), at the cost of
/// `b`× the dispatch work the true-batch scaffold amortizes.
pub(crate) fn lm_serve_scaffold_batched_replicated(
    graph_name: &str,
    m: &ModelShape,
    b: usize,
    t: usize,
    mut block: impl FnMut(&mut Ctx, usize, NodeId) -> (NodeId, (NodeId, NodeId)),
) -> Graph {
    assert!(b >= 1, "prefill bucket must be >= 1");
    let spec = full_spec(m);
    let mut ctx = Ctx::new(graph_name, &spec);
    let tokens = ctx.g.input_i32("tokens", vec![b, t]);
    let emb = ctx.w("emb");
    // sequence-independent, so built once: every sequence's lm-head
    // matmul consumes the identical transpose values (bitwise-neutral
    // vs. the single-sequence graph's own transpose of the same `emb`)
    let emb_t = ctx.g.transpose(emb, vec![1, 0], "lm_head.wT");
    let mut logits_rows: Vec<NodeId> = Vec::with_capacity(b);
    let mut conv_rows: Vec<Vec<NodeId>> = vec![Vec::with_capacity(b); m.n_layers];
    let mut ssm_rows: Vec<Vec<NodeId>> = vec![Vec::with_capacity(b); m.n_layers];
    for s in 0..b {
        let tok_row = ctx.g.slice(tokens, 0, s, 1, &format!("s{s}.tokens.row"));
        let tok = ctx.g.reshape(tok_row, vec![t], &format!("s{s}.tokens"));
        let mut x = ctx.g.gather(emb, tok, &format!("s{s}.embed"));
        let mut states: Vec<(NodeId, NodeId)> = Vec::with_capacity(m.n_layers);
        for j in 0..m.n_layers {
            let norm_w = ctx.w(&format!("l{j}.norm_w"));
            let xn = ctx.g.rmsnorm(x, norm_w, &format!("l{j}.norm"));
            let (y, st) = block(&mut ctx, j, xn);
            states.push(st);
            x = ctx.g.add(x, y, &format!("l{j}.residual"));
        }
        let fw = ctx.w("final_norm_w");
        let xf = ctx.g.rmsnorm(x, fw, &format!("s{s}.final_norm"));
        let x_last = ctx.g.slice(xf, 0, t - 1, 1, &format!("s{s}.last_pos"));
        logits_rows.push(ctx.g.matmul(x_last, emb_t, &format!("s{s}.lm_head.mm")));
        for (j, (cs, ss)) in states.into_iter().enumerate() {
            let cs_shape = stacked1(ctx.g.shape(cs));
            let ss_shape = stacked1(ctx.g.shape(ss));
            conv_rows[j]
                .push(ctx.g.reshape(cs, cs_shape, &format!("s{s}.l{j}.conv.stack")));
            ssm_rows[j]
                .push(ctx.g.reshape(ss, ss_shape, &format!("s{s}.l{j}.ssm.stack")));
        }
    }
    let logits = ctx.g.concat(&logits_rows, 0, "logits.batch"); // (b, V)
    ctx.g.output(logits);
    for j in 0..m.n_layers {
        let cs = ctx.g.concat(&conv_rows[j], 0, &format!("l{j}.conv.batch"));
        let ss = ctx.g.concat(&ssm_rows[j], 0, &format!("l{j}.ssm.batch"));
        ctx.g.output(cs);
        ctx.g.output(ss);
    }
    ctx.g
}

/// `[1] ++ shape` — one sequence's slot in the batch-stacked state.
fn stacked1(shape: &[usize]) -> Vec<usize> {
    let mut s = Vec::with_capacity(1 + shape.len());
    s.push(1);
    s.extend_from_slice(shape);
    s
}

/// Which model family a serving backend drives. Constructed from
/// `ModelShape.arch` via [`ServeFamily::from_arch`]; every family-specific
/// decision on the planned serving path (graph builders, state-tensor
/// layout, plan-cache key prefix) dispatches through here.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeFamily {
    Mamba1,
    Mamba2,
}

impl ServeFamily {
    /// Resolve an architecture string; unknown arch is a clear error, not
    /// a panic (the coordinator surfaces it as a config error).
    pub fn from_arch(arch: &str) -> Result<ServeFamily, String> {
        match arch {
            "mamba" => Ok(ServeFamily::Mamba1),
            "mamba2" => Ok(ServeFamily::Mamba2),
            other => Err(format!(
                "no planned serving family for arch {other:?} (want \"mamba\" or \"mamba2\")"
            )),
        }
    }

    /// The `ModelShape.arch` string this family serves — also the model
    /// half of every plan-cache key (e.g. `mamba2.decode_b4`).
    pub fn arch(self) -> &'static str {
        match self {
            ServeFamily::Mamba1 => "mamba",
            ServeFamily::Mamba2 => "mamba2",
        }
    }

    /// Serving prefill graph: tokens (T,) i32 → last-position logits
    /// (1, V) + per-layer `(conv_state, ssm_state)` in layer order.
    pub fn build_prefill_serve(self, m: &ModelShape, t: usize) -> Graph {
        match self {
            ServeFamily::Mamba1 => mamba1::build_prefill_serve(m, t),
            ServeFamily::Mamba2 => mamba2::build_prefill_serve(m, t),
        }
    }

    /// Resume-prefill graph: tokens (t,) i32 + per-layer
    /// `(conv_state, ssm_state)` *inputs* → last-position logits (1, V) +
    /// per-layer new states (same output layout as
    /// [`ServeFamily::build_prefill_serve`]). Continues a checkpointed
    /// state across a chunk boundary: the conv input carries the raw
    /// pre-conv tail of the last K-1 tokens, the ssm input seeds the
    /// scan / SSD recurrence, so every resumed position sees exactly the
    /// values the monolithic graph would have computed.
    pub fn build_prefill_resume(self, m: &ModelShape, t: usize) -> Graph {
        match self {
            ServeFamily::Mamba1 => mamba1::build_prefill_serve_resume(m, t),
            ServeFamily::Mamba2 => mamba2::build_prefill_serve_resume(m, t),
        }
    }

    /// Token grain at which a chunk-boundary checkpoint resumes **bitwise
    /// identically** to the monolithic prefill. Mamba-1's scan is strictly
    /// sequential, so any boundary works (grain 1). Mamba-2's SSD
    /// reassociates within each chunk — splitting mid-chunk changes the
    /// reduction order — so boundaries must land on multiples of
    /// `m.chunk`. (Resuming from a decode-produced state is decode-exact
    /// at ANY offset; the grain only governs bitwise equality with a
    /// from-scratch prefill.)
    pub fn resume_chunk_grain(self, m: &ModelShape) -> usize {
        match self {
            ServeFamily::Mamba1 => 1,
            ServeFamily::Mamba2 => m.chunk,
        }
    }

    /// Batched decode-step graph for bucket `b`: tokens (b,) i32 +
    /// per-layer stacked states → logits (b, V) + new states.
    pub fn build_decode_batched(self, m: &ModelShape, b: usize) -> Graph {
        match self {
            ServeFamily::Mamba1 => mamba1::build_decode_batched(m, b),
            ServeFamily::Mamba2 => mamba2::build_decode_batched(m, b),
        }
    }

    /// Speculative-verify graph for bucket `b` and window `kw`: tokens
    /// (b, kw) i32 + per-layer stacked states → logits at ALL kw
    /// positions (b, kw, V) + states advanced kw steps. Unlike the
    /// serve-prefill graphs (last-position logits, conv bias-first),
    /// this is [`ServeFamily::build_decode_batched`] unrolled kw times —
    /// position p's logits and the final states are **bitwise
    /// identical** to kw sequential decode steps, which is what lets
    /// speculative acceptance/rollback reproduce non-speculative output
    /// exactly. f32/f16 only; i8's dynamic per-tensor activation scales
    /// would couple the kw positions inside one node.
    pub fn build_verify(self, m: &ModelShape, b: usize, kw: usize) -> Graph {
        match self {
            ServeFamily::Mamba1 => mamba1::build_verify_batched(m, b, kw),
            ServeFamily::Mamba2 => mamba2::build_verify_batched(m, b, kw),
        }
    }

    /// Batched serving-prefill graph for prefill bucket `b`: tokens
    /// (b, t) i32 → logits (b, V) + per-layer batch-stacked states,
    /// per-sequence bitwise identical to
    /// [`ServeFamily::build_prefill_serve`] at the same `t`. The batch
    /// dimension lives inside every node — one (b, t)-shaped step per op
    /// (see [`lm_serve_scaffold_batched`]).
    pub fn build_prefill_batched(self, m: &ModelShape, b: usize, t: usize) -> Graph {
        match self {
            ServeFamily::Mamba1 => mamba1::build_prefill_serve_batched(m, b, t),
            ServeFamily::Mamba2 => mamba2::build_prefill_serve_batched(m, b, t),
        }
    }

    /// Replicated batched serving-prefill graph: same I/O contract as
    /// [`ServeFamily::build_prefill_batched`], but each sequence runs its
    /// own copy of the single-sequence graph (see
    /// [`lm_serve_scaffold_batched_replicated`]). The coordinator routes
    /// i8 serving here: dynamic per-tensor requantize scales inside a
    /// true-batch node would couple co-batched sequences.
    pub fn build_prefill_batched_replicated(
        self,
        m: &ModelShape,
        b: usize,
        t: usize,
    ) -> Graph {
        match self {
            ServeFamily::Mamba1 => mamba1::build_prefill_serve_batched_replicated(m, b, t),
            ServeFamily::Mamba2 => mamba2::build_prefill_serve_batched_replicated(m, b, t),
        }
    }

    /// Per-layer, per-sequence conv-state shape.
    pub fn conv_state_shape(self, m: &ModelShape) -> Vec<usize> {
        vec![m.d_conv - 1, m.conv_dim()]
    }

    /// Per-layer, per-sequence recurrent-state shape: `(d_inner, N)` for
    /// Mamba-1's selective scan, `(H, P, N)` for Mamba-2's SSD heads.
    pub fn ssm_state_shape(self, m: &ModelShape) -> Vec<usize> {
        match self {
            ServeFamily::Mamba1 => vec![m.d_inner(), m.d_state],
            ServeFamily::Mamba2 => vec![m.n_heads(), m.headdim, m.d_state],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn family_resolves_known_archs_only() {
        assert_eq!(ServeFamily::from_arch("mamba"), Ok(ServeFamily::Mamba1));
        assert_eq!(ServeFamily::from_arch("mamba2"), Ok(ServeFamily::Mamba2));
        let err = ServeFamily::from_arch("transformer").unwrap_err();
        assert!(err.contains("transformer") && err.contains("mamba2"), "{err}");
    }

    #[test]
    fn state_layouts_match_the_decode_graph_io() {
        for m in [presets::tiny_mamba(), presets::tiny_mamba2()] {
            let f = ServeFamily::from_arch(&m.arch).unwrap();
            let g = f.build_decode_batched(&m, 2);
            assert_eq!(&g.shape(g.outputs[1])[1..], f.conv_state_shape(&m).as_slice());
            assert_eq!(&g.shape(g.outputs[2])[1..], f.ssm_state_shape(&m).as_slice());
        }
    }

    #[test]
    fn resume_prefill_io_matches_the_serve_prefill_layout() {
        // the resume graph's state INPUTS and OUTPUTS must both use the
        // per-sequence layouts the serve-prefill graph emits, so a
        // checkpoint round-trips without reshaping
        let t = 5usize;
        for m in [presets::tiny_mamba(), presets::tiny_mamba2()] {
            let f = ServeFamily::from_arch(&m.arch).unwrap();
            let g = f.build_prefill_resume(&m, t);
            assert_eq!(g.outputs.len(), 1 + 2 * m.n_layers);
            assert_eq!(g.shape(g.outputs[0]), &[1, m.vocab_size]);
            for j in 0..m.n_layers {
                assert_eq!(
                    g.shape(g.outputs[1 + 2 * j]),
                    f.conv_state_shape(&m).as_slice(),
                    "{} conv out", m.arch
                );
                assert_eq!(
                    g.shape(g.outputs[2 + 2 * j]),
                    f.ssm_state_shape(&m).as_slice(),
                    "{} ssm out", m.arch
                );
            }
            // state inputs follow the params + tokens in layer order
            let n_params = g.inputs.len() - 1 - 2 * m.n_layers;
            for j in 0..m.n_layers {
                let conv_in = g.inputs[n_params + 1 + 2 * j];
                let ssm_in = g.inputs[n_params + 2 + 2 * j];
                assert_eq!(g.shape(conv_in), f.conv_state_shape(&m).as_slice());
                assert_eq!(g.shape(ssm_in), f.ssm_state_shape(&m).as_slice());
            }
        }
    }

    #[test]
    fn resume_grain_is_sequential_for_mamba1_and_chunked_for_mamba2() {
        let m1 = presets::tiny_mamba();
        let m2 = presets::tiny_mamba2();
        assert_eq!(ServeFamily::Mamba1.resume_chunk_grain(&m1), 1);
        assert_eq!(ServeFamily::Mamba2.resume_chunk_grain(&m2), m2.chunk);
    }

    #[test]
    fn verify_graph_io_matches_the_decode_layout() {
        // verify outputs stack exactly like batched decode's, with the
        // window axis only on the logits — the coordinator unpacks
        // states with the same code path
        let (b, kw) = (2usize, 3usize);
        for m in [presets::tiny_mamba(), presets::tiny_mamba2()] {
            let f = ServeFamily::from_arch(&m.arch).unwrap();
            let g = f.build_verify(&m, b, kw);
            assert_eq!(g.outputs.len(), 1 + 2 * m.n_layers);
            assert_eq!(g.shape(g.outputs[0]), &[b, kw, m.vocab_size]);
            let mut conv = vec![b];
            conv.extend(f.conv_state_shape(&m));
            let mut ssm = vec![b];
            ssm.extend(f.ssm_state_shape(&m));
            assert_eq!(g.shape(g.outputs[1]), conv.as_slice(), "{}", m.arch);
            assert_eq!(g.shape(g.outputs[2]), ssm.as_slice(), "{}", m.arch);
        }
    }

    #[test]
    fn batched_prefill_io_matches_the_decode_layout() {
        // the batched prefill's outputs must stack exactly like the
        // batched decode inputs, so the coordinator can unpack both with
        // one code path
        let (b, t) = (3usize, 9usize);
        for m in [presets::tiny_mamba(), presets::tiny_mamba2()] {
            let f = ServeFamily::from_arch(&m.arch).unwrap();
            for g in [
                f.build_prefill_batched(&m, b, t),
                f.build_prefill_batched_replicated(&m, b, t),
            ] {
                assert_eq!(g.outputs.len(), 1 + 2 * m.n_layers);
                assert_eq!(g.shape(g.outputs[0]), &[b, m.vocab_size]);
                let mut conv = vec![b];
                conv.extend(f.conv_state_shape(&m));
                let mut ssm = vec![b];
                ssm.extend(f.ssm_state_shape(&m));
                assert_eq!(g.shape(g.outputs[1]), conv.as_slice(), "{}", m.arch);
                assert_eq!(g.shape(g.outputs[2]), ssm.as_slice(), "{}", m.arch);
            }
        }
    }
}
