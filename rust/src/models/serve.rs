//! Model-generic serving builders — the seam `coordinator::PlannedServeModel`
//! selects a model family through.
//!
//! Both Mamba families serve through the same two graph shapes:
//!
//! * a **serve prefill** (tokens → last-position logits + per-layer
//!   decode-ready recurrent state), and
//! * a per-bucket **batched decode step** (tokens (b,) + stacked states →
//!   logits (b, V) + new states).
//!
//! What differs per family is the block math and the *state layout*:
//! Mamba-1 carries `conv (K-1, d_inner)` + `ssm (d_inner, N)`, Mamba-2
//! carries `conv (K-1, d_inner + 2N)` (x, B, C conv together) +
//! the SSD state `ssm (H, P, N)`. [`ServeFamily`] owns both the builder
//! dispatch and the layout so the coordinator never hardcodes either.

use crate::config::ModelShape;
use crate::graph::{Graph, NodeId};

use super::mamba1::Ctx;
use super::params::full_spec;
use super::{mamba1, mamba2};

/// The LM-level scaffolding shared by every serve-prefill graph: embed →
/// per-layer (rmsnorm → block → residual) → final norm → last-position
/// logits, with per-layer `(conv_state, ssm_state)` outputs appended in
/// [`ServeFamily`] order. `block` builds one family-specific block over
/// the normalized input and returns `(block_out, (conv_state, ssm_state))`.
pub(crate) fn lm_serve_scaffold(
    graph_name: &str,
    m: &ModelShape,
    t: usize,
    mut block: impl FnMut(&mut Ctx, usize, NodeId) -> (NodeId, (NodeId, NodeId)),
) -> Graph {
    let spec = full_spec(m);
    let mut ctx = Ctx::new(graph_name, &spec);
    let tokens = ctx.g.input_i32("tokens", vec![t]);
    let emb = ctx.w("emb");
    let mut x = ctx.g.gather(emb, tokens, "embed");
    let mut states: Vec<(NodeId, NodeId)> = Vec::with_capacity(m.n_layers);
    for j in 0..m.n_layers {
        let norm_w = ctx.w(&format!("l{j}.norm_w"));
        let xn = ctx.g.rmsnorm(x, norm_w, &format!("l{j}.norm"));
        let (y, st) = block(&mut ctx, j, xn);
        states.push(st);
        x = ctx.g.add(x, y, &format!("l{j}.residual"));
    }
    let fw = ctx.w("final_norm_w");
    let x = ctx.g.rmsnorm(x, fw, "final_norm");
    let x_last = ctx.g.slice(x, 0, t - 1, 1, "last_pos");
    let emb_t = ctx.g.transpose(emb, vec![1, 0], "lm_head.wT");
    let logits = ctx.g.matmul(x_last, emb_t, "lm_head.mm"); // (1, V)
    ctx.g.output(logits);
    for (cs, ss) in states {
        ctx.g.output(cs);
        ctx.g.output(ss);
    }
    ctx.g
}

/// Which model family a serving backend drives. Constructed from
/// `ModelShape.arch` via [`ServeFamily::from_arch`]; every family-specific
/// decision on the planned serving path (graph builders, state-tensor
/// layout, plan-cache key prefix) dispatches through here.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeFamily {
    Mamba1,
    Mamba2,
}

impl ServeFamily {
    /// Resolve an architecture string; unknown arch is a clear error, not
    /// a panic (the coordinator surfaces it as a config error).
    pub fn from_arch(arch: &str) -> Result<ServeFamily, String> {
        match arch {
            "mamba" => Ok(ServeFamily::Mamba1),
            "mamba2" => Ok(ServeFamily::Mamba2),
            other => Err(format!(
                "no planned serving family for arch {other:?} (want \"mamba\" or \"mamba2\")"
            )),
        }
    }

    /// The `ModelShape.arch` string this family serves — also the model
    /// half of every plan-cache key (e.g. `mamba2.decode_b4`).
    pub fn arch(self) -> &'static str {
        match self {
            ServeFamily::Mamba1 => "mamba",
            ServeFamily::Mamba2 => "mamba2",
        }
    }

    /// Serving prefill graph: tokens (T,) i32 → last-position logits
    /// (1, V) + per-layer `(conv_state, ssm_state)` in layer order.
    pub fn build_prefill_serve(self, m: &ModelShape, t: usize) -> Graph {
        match self {
            ServeFamily::Mamba1 => mamba1::build_prefill_serve(m, t),
            ServeFamily::Mamba2 => mamba2::build_prefill_serve(m, t),
        }
    }

    /// Batched decode-step graph for bucket `b`: tokens (b,) i32 +
    /// per-layer stacked states → logits (b, V) + new states.
    pub fn build_decode_batched(self, m: &ModelShape, b: usize) -> Graph {
        match self {
            ServeFamily::Mamba1 => mamba1::build_decode_batched(m, b),
            ServeFamily::Mamba2 => mamba2::build_decode_batched(m, b),
        }
    }

    /// Per-layer, per-sequence conv-state shape.
    pub fn conv_state_shape(self, m: &ModelShape) -> Vec<usize> {
        vec![m.d_conv - 1, m.conv_dim()]
    }

    /// Per-layer, per-sequence recurrent-state shape: `(d_inner, N)` for
    /// Mamba-1's selective scan, `(H, P, N)` for Mamba-2's SSD heads.
    pub fn ssm_state_shape(self, m: &ModelShape) -> Vec<usize> {
        match self {
            ServeFamily::Mamba1 => vec![m.d_inner(), m.d_state],
            ServeFamily::Mamba2 => vec![m.n_heads(), m.headdim, m.d_state],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn family_resolves_known_archs_only() {
        assert_eq!(ServeFamily::from_arch("mamba"), Ok(ServeFamily::Mamba1));
        assert_eq!(ServeFamily::from_arch("mamba2"), Ok(ServeFamily::Mamba2));
        let err = ServeFamily::from_arch("transformer").unwrap_err();
        assert!(err.contains("transformer") && err.contains("mamba2"), "{err}");
    }

    #[test]
    fn state_layouts_match_the_decode_graph_io() {
        for m in [presets::tiny_mamba(), presets::tiny_mamba2()] {
            let f = ServeFamily::from_arch(&m.arch).unwrap();
            let g = f.build_decode_batched(&m, 2);
            assert_eq!(&g.shape(g.outputs[1])[1..], f.conv_state_shape(&m).as_slice());
            assert_eq!(&g.shape(g.outputs[2])[1..], f.ssm_state_shape(&m).as_slice());
        }
    }
}
