//! Mamba-1 model as an IR graph (mirror of `python/compile/mamba.py`).
//!
//! The selective scan is *unrolled over time* — exactly what a static-
//! shape NPU conversion does (the paper's T=4 ONNX graphs are unrolled) —
//! so the census and the cost model see the true operator mix: staged
//! projections, depthwise conv, the Fig-1 bottleneck activations (Swish,
//! Softplus), and a long chain of small elementwise ops for the scan.

use std::collections::HashMap;

use crate::config::ModelShape;
use crate::graph::{Graph, NodeId};

use super::params::{full_spec, ParamSpec};

/// Graph + named parameter nodes under construction.
pub(crate) struct Ctx {
    pub g: Graph,
    pub p: HashMap<String, NodeId>,
}

impl Ctx {
    /// Declare every parameter in `spec` as a graph input (ABI order).
    pub fn new(name: &str, spec: &ParamSpec) -> Self {
        let mut g = Graph::new(name);
        let mut p = HashMap::new();
        for e in &spec.entries {
            let id = g.input(&e.name, e.shape.clone());
            p.insert(e.name.clone(), id);
        }
        Self { g, p }
    }

    pub fn w(&self, name: &str) -> NodeId {
        *self
            .p
            .get(name)
            .unwrap_or_else(|| panic!("unknown param {name}"))
    }
}

/// One Mamba-1 block over `x` (T, d_model); returns the block output
/// (pre-residual). Ops named `l{j}.*` for profiling attribution.
pub(crate) fn block_prefill(
    ctx: &mut Ctx,
    m: &ModelShape,
    j: usize,
    x: NodeId,
    t: usize,
) -> NodeId {
    block_prefill_with_state(ctx, m, j, x, t).0
}

/// Like `block_prefill` but also returns the nodes a serving prefill
/// needs to seed decode: the conv input sequence `xi` (T, d_inner) —
/// its last K-1 rows are the decode-time conv state — and the final
/// scan state `h_T` (d_inner, d_state).
pub(crate) fn block_prefill_with_state(
    ctx: &mut Ctx,
    m: &ModelShape,
    j: usize,
    x: NodeId,
    t: usize,
) -> (NodeId, NodeId, NodeId) {
    let (di, n) = (m.d_inner(), m.d_state);
    let r = m.resolved_dt_rank();
    let nm = |s: &str| format!("l{j}.{s}");
    let w = |ctx: &Ctx, s: &str| ctx.w(&nm(s));

    // staged projections (appendix A.1: Mamba-1 projects in stages)
    let in_proj = w(&*ctx, "in_proj");
    let xz = ctx.g.matmul(x, in_proj, &nm("in_proj.mm"));
    let xi = ctx.g.slice(xz, 1, 0, di, &nm("split.x"));
    let z = ctx.g.slice(xz, 1, di, di, &nm("split.z"));

    // depthwise causal conv + SiLU (bottleneck activation #1)
    let (cw, cb) = (w(&*ctx, "conv_w"), w(&*ctx, "conv_b"));
    let xc = ctx.g.conv1d_causal(xi, cw, cb, &nm("conv"));
    let xc = ctx.g.silu(xc, &nm("conv.silu"));

    // selective parameters dt, B, C
    let xp = w(&*ctx, "x_proj");
    let xdbc = ctx.g.matmul(xc, xp, &nm("x_proj.mm"));
    let dt_r = ctx.g.slice(xdbc, 1, 0, r, &nm("split.dt"));
    let b_sel = ctx.g.slice(xdbc, 1, r, n, &nm("split.B"));
    let c_sel = ctx.g.slice(xdbc, 1, r + n, n, &nm("split.C"));
    let (dtw, dtb) = (w(&*ctx, "dt_proj_w"), w(&*ctx, "dt_proj_b"));
    let dt_full = ctx.g.matmul(dt_r, dtw, &nm("dt_proj.mm"));
    let dt_full = ctx.g.add(dt_full, dtb, &nm("dt_proj.bias"));
    // Softplus (bottleneck activation #2)
    let dt = ctx.g.softplus(dt_full, &nm("dt.softplus"));

    // A = -exp(a_log)
    let a_log = w(&*ctx, "a_log");
    let a_exp = ctx.g.exp(a_log, &nm("A.exp"));
    let neg1 = ctx.g.const_scalar(&nm("A.neg1"), -1.0);
    let a = ctx.g.mul(a_exp, neg1, &nm("A"));
    let d_skip = w(&*ctx, "d_skip");

    // --- unrolled selective scan (static-shape NPU style) --------------
    // h_t = exp(dt_t A) h_{t-1} + (dt_t x_t) B_t ; y_t = h_t C_t + D x_t
    let mut h: Option<NodeId> = None;
    let mut ys: Vec<NodeId> = Vec::with_capacity(t);
    for step in 0..t {
        let snm = |s: &str| format!("l{j}.scan{step}.{s}");
        let x_t = ctx.g.slice(xc, 0, step, 1, &snm("x"));   // (1, di)
        let dt_t = ctx.g.slice(dt, 0, step, 1, &snm("dt")); // (1, di)
        let b_t = ctx.g.slice(b_sel, 0, step, 1, &snm("B")); // (1, n)
        let c_t = ctx.g.slice(c_sel, 0, step, 1, &snm("C")); // (1, n)
        let dt_col = ctx.g.reshape(dt_t, vec![di, 1], &snm("dt.col"));
        let da = ctx.g.mul(dt_col, a, &snm("dtA")); // (di, n)
        let da = ctx.g.exp(da, &snm("decay"));
        let xdt = ctx.g.mul(dt_t, x_t, &snm("x.dt")); // (1, di)
        let xdt_col = ctx.g.reshape(xdt, vec![di, 1], &snm("x.dt.col"));
        let inflow = ctx.g.mul(xdt_col, b_t, &snm("inflow")); // (di, n)
        let h_new = match h {
            None => inflow, // h0 = 0
            Some(prev) => {
                let decayed = ctx.g.mul(da, prev, &snm("h.decay"));
                ctx.g.add(decayed, inflow, &snm("h"))
            }
        };
        h = Some(h_new);
        let c_col = ctx.g.reshape(c_t, vec![n, 1], &snm("C.col"));
        let y_t = ctx.g.matmul(h_new, c_col, &snm("y.mm")); // (di, 1)
        let y_row = ctx.g.reshape(y_t, vec![1, di], &snm("y.row"));
        let skip = ctx.g.mul(x_t, d_skip, &snm("y.skip"));
        ys.push(ctx.g.add(y_row, skip, &snm("y")));
    }
    let y = ctx.g.concat(&ys, 0, &nm("scan.y")); // (T, di)

    // gate with SiLU(z) (bottleneck activation #1 again), project out
    let zg = ctx.g.silu(z, &nm("gate.silu"));
    let y = ctx.g.mul(y, zg, &nm("gate.mul"));
    let op = w(&*ctx, "out_proj");
    let out = ctx.g.matmul(y, op, &nm("out_proj.mm"));
    (out, xi, h.expect("scan needs t >= 1"))
}

/// Resume variant of [`block_prefill_with_state`]: `conv_in` (K-1,
/// d_inner) carries the raw pre-conv rows of the previous chunk's last
/// K-1 tokens and `ssm_in` (d_inner, N) seeds the scan recurrence, so
/// every resumed position computes exactly the values the monolithic
/// block computes at the same global offset: the conv window is complete
/// (no zero-padded edge), SiLU/x_proj/dt act per row, and each scan step
/// takes the same carry expression `h' = exp(dt A) h + (dt x) B` the
/// monolithic scan uses from step 1 on. Returns `(block_out,
/// new_conv_state (K-1, d_inner), h_last (d_inner, N))`.
pub(crate) fn block_prefill_resume_with_state(
    ctx: &mut Ctx,
    m: &ModelShape,
    j: usize,
    x: NodeId,
    t: usize,
    conv_in: NodeId,
    ssm_in: NodeId,
) -> (NodeId, NodeId, NodeId) {
    let (di, n, k) = (m.d_inner(), m.d_state, m.d_conv);
    let r = m.resolved_dt_rank();
    let nm = |s: &str| format!("l{j}.{s}");
    let w = |ctx: &Ctx, s: &str| ctx.w(&nm(s));

    let in_proj = w(&*ctx, "in_proj");
    let xz = ctx.g.matmul(x, in_proj, &nm("in_proj.mm"));
    let xi = ctx.g.slice(xz, 1, 0, di, &nm("split.x"));
    let z = ctx.g.slice(xz, 1, di, di, &nm("split.z"));

    // extend the raw conv input with the carried tail, run the causal
    // conv over (K-1+T, di), then keep only the T new rows — each has a
    // full real window
    let ext = ctx.g.concat(&[conv_in, xi], 0, &nm("conv.ext"));
    let (cw, cb) = (w(&*ctx, "conv_w"), w(&*ctx, "conv_b"));
    let xc_ext = ctx.g.conv1d_causal(ext, cw, cb, &nm("conv"));
    let xc = ctx.g.slice(xc_ext, 0, k - 1, t, &nm("conv.new"));
    let xc = ctx.g.silu(xc, &nm("conv.silu"));
    // next chunk's carry: the last K-1 raw rows of the extended sequence
    // (valid for any t >= 1 — short chunks keep part of the old tail)
    let new_conv = ctx.g.slice(ext, 0, t, k - 1, &nm("conv.state"));

    let xp = w(&*ctx, "x_proj");
    let xdbc = ctx.g.matmul(xc, xp, &nm("x_proj.mm"));
    let dt_r = ctx.g.slice(xdbc, 1, 0, r, &nm("split.dt"));
    let b_sel = ctx.g.slice(xdbc, 1, r, n, &nm("split.B"));
    let c_sel = ctx.g.slice(xdbc, 1, r + n, n, &nm("split.C"));
    let (dtw, dtb) = (w(&*ctx, "dt_proj_w"), w(&*ctx, "dt_proj_b"));
    let dt_full = ctx.g.matmul(dt_r, dtw, &nm("dt_proj.mm"));
    let dt_full = ctx.g.add(dt_full, dtb, &nm("dt_proj.bias"));
    let dt = ctx.g.softplus(dt_full, &nm("dt.softplus"));

    let a_log = w(&*ctx, "a_log");
    let a_exp = ctx.g.exp(a_log, &nm("A.exp"));
    let neg1 = ctx.g.const_scalar(&nm("A.neg1"), -1.0);
    let a = ctx.g.mul(a_exp, neg1, &nm("A"));
    let d_skip = w(&*ctx, "d_skip");

    // unrolled scan seeded from the carried state: EVERY step (step 0
    // included) takes the carry path, matching the monolithic scan's
    // steps >= 1
    let mut h = ssm_in;
    let mut ys: Vec<NodeId> = Vec::with_capacity(t);
    for step in 0..t {
        let snm = |s: &str| format!("l{j}.scan{step}.{s}");
        let x_t = ctx.g.slice(xc, 0, step, 1, &snm("x"));
        let dt_t = ctx.g.slice(dt, 0, step, 1, &snm("dt"));
        let b_t = ctx.g.slice(b_sel, 0, step, 1, &snm("B"));
        let c_t = ctx.g.slice(c_sel, 0, step, 1, &snm("C"));
        let dt_col = ctx.g.reshape(dt_t, vec![di, 1], &snm("dt.col"));
        let da = ctx.g.mul(dt_col, a, &snm("dtA"));
        let da = ctx.g.exp(da, &snm("decay"));
        let xdt = ctx.g.mul(dt_t, x_t, &snm("x.dt"));
        let xdt_col = ctx.g.reshape(xdt, vec![di, 1], &snm("x.dt.col"));
        let inflow = ctx.g.mul(xdt_col, b_t, &snm("inflow"));
        let decayed = ctx.g.mul(da, h, &snm("h.decay"));
        let h_new = ctx.g.add(decayed, inflow, &snm("h"));
        h = h_new;
        let c_col = ctx.g.reshape(c_t, vec![n, 1], &snm("C.col"));
        let y_t = ctx.g.matmul(h_new, c_col, &snm("y.mm"));
        let y_row = ctx.g.reshape(y_t, vec![1, di], &snm("y.row"));
        let skip = ctx.g.mul(x_t, d_skip, &snm("y.skip"));
        ys.push(ctx.g.add(y_row, skip, &snm("y")));
    }
    let y = if ys.len() == 1 {
        ys[0]
    } else {
        ctx.g.concat(&ys, 0, &nm("scan.y"))
    };

    let zg = ctx.g.silu(z, &nm("gate.silu"));
    let y = ctx.g.mul(y, zg, &nm("gate.mul"));
    let op = w(&*ctx, "out_proj");
    let out = ctx.g.matmul(y, op, &nm("out_proj.mm"));
    (out, new_conv, h)
}

/// Batched counterpart of [`block_prefill_with_state`]: one rank-3 node
/// per op over `x` (B, T, d_model) instead of `B` replicas of the
/// single-sequence block. Every op treats the leading batch dimension
/// independently — matmuls against shared rank-2 weights walk rows, the
/// conv and the unrolled scan slice along the time axis, broadcasts
/// reuse the same parameter values per sequence — so each sequence's
/// results are bitwise identical to the single-sequence block. Returns
/// `(block_out (B, T, d_model), conv input sequence (B, T, d_inner),
/// final scan state (B, d_inner, N))`.
pub(crate) fn block_prefill_batched_with_state(
    ctx: &mut Ctx,
    m: &ModelShape,
    j: usize,
    x: NodeId,
    b: usize,
    t: usize,
) -> (NodeId, NodeId, NodeId) {
    let (di, n) = (m.d_inner(), m.d_state);
    let r = m.resolved_dt_rank();
    let nm = |s: &str| format!("l{j}.{s}");
    let w = |ctx: &Ctx, s: &str| ctx.w(&nm(s));

    // staged projections: rank-3 activations against the shared weights
    let in_proj = w(&*ctx, "in_proj");
    let xz = ctx.g.matmul(x, in_proj, &nm("in_proj.mm")); // (B, T, 2di)
    let xi = ctx.g.slice(xz, 2, 0, di, &nm("split.x"));
    let z = ctx.g.slice(xz, 2, di, di, &nm("split.z"));

    // depthwise causal conv (batch-aware kernel) + SiLU
    let (cw, cb) = (w(&*ctx, "conv_w"), w(&*ctx, "conv_b"));
    let xc = ctx.g.conv1d_causal(xi, cw, cb, &nm("conv")); // (B, T, di)
    let xc = ctx.g.silu(xc, &nm("conv.silu"));

    // selective parameters dt, B, C
    let xp = w(&*ctx, "x_proj");
    let xdbc = ctx.g.matmul(xc, xp, &nm("x_proj.mm")); // (B, T, r+2n)
    let dt_r = ctx.g.slice(xdbc, 2, 0, r, &nm("split.dt"));
    let b_sel = ctx.g.slice(xdbc, 2, r, n, &nm("split.B"));
    let c_sel = ctx.g.slice(xdbc, 2, r + n, n, &nm("split.C"));
    let (dtw, dtb) = (w(&*ctx, "dt_proj_w"), w(&*ctx, "dt_proj_b"));
    let dt_full = ctx.g.matmul(dt_r, dtw, &nm("dt_proj.mm"));
    let dt_full = ctx.g.add(dt_full, dtb, &nm("dt_proj.bias"));
    let dt = ctx.g.softplus(dt_full, &nm("dt.softplus")); // (B, T, di)

    let a_log = w(&*ctx, "a_log");
    let a_exp = ctx.g.exp(a_log, &nm("A.exp"));
    let neg1 = ctx.g.const_scalar(&nm("A.neg1"), -1.0);
    let a = ctx.g.mul(a_exp, neg1, &nm("A")); // (di, n)
    let d_skip = w(&*ctx, "d_skip");

    // unrolled scan, batch-stacked: each step advances all B sequences
    // through one (B, di, n) node set
    let mut hstate: Option<NodeId> = None;
    let mut ys: Vec<NodeId> = Vec::with_capacity(t);
    for step in 0..t {
        let snm = |s: &str| format!("l{j}.scan{step}.{s}");
        let x_t = ctx.g.slice(xc, 1, step, 1, &snm("x"));   // (B, 1, di)
        let dt_t = ctx.g.slice(dt, 1, step, 1, &snm("dt")); // (B, 1, di)
        let b_t = ctx.g.slice(b_sel, 1, step, 1, &snm("B")); // (B, 1, n)
        let c_t = ctx.g.slice(c_sel, 1, step, 1, &snm("C")); // (B, 1, n)
        let dt_col = ctx.g.reshape(dt_t, vec![b, di, 1], &snm("dt.col"));
        let da = ctx.g.mul(dt_col, a, &snm("dtA")); // (B, di, n)
        let da = ctx.g.exp(da, &snm("decay"));
        let xdt = ctx.g.mul(dt_t, x_t, &snm("x.dt")); // (B, 1, di)
        let xdt_col = ctx.g.reshape(xdt, vec![b, di, 1], &snm("x.dt.col"));
        let inflow = ctx.g.mul(xdt_col, b_t, &snm("inflow")); // (B, di, n)
        let h_new = match hstate {
            None => inflow, // h0 = 0
            Some(prev) => {
                let decayed = ctx.g.mul(da, prev, &snm("h.decay"));
                ctx.g.add(decayed, inflow, &snm("h"))
            }
        };
        hstate = Some(h_new);
        let c_col = ctx.g.reshape(c_t, vec![b, n, 1], &snm("C.col"));
        let y_t = ctx.g.matmul(h_new, c_col, &snm("y.mm")); // (B, di, 1)
        let y_row = ctx.g.reshape(y_t, vec![b, 1, di], &snm("y.row"));
        let skip = ctx.g.mul(x_t, d_skip, &snm("y.skip"));
        ys.push(ctx.g.add(y_row, skip, &snm("y")));
    }
    let y = ctx.g.concat(&ys, 1, &nm("scan.y")); // (B, T, di)

    let zg = ctx.g.silu(z, &nm("gate.silu"));
    let y = ctx.g.mul(y, zg, &nm("gate.mul"));
    let op = w(&*ctx, "out_proj");
    let out = ctx.g.matmul(y, op, &nm("out_proj.mm"));
    (out, xi, hstate.expect("scan needs t >= 1"))
}

/// Full Mamba-1 LM prefill graph: tokens (T,) i32 -> logits (T, V).
///
/// Inputs: every parameter (ParamSpec order), then `tokens`.
pub fn build_prefill(m: &ModelShape, t: usize) -> Graph {
    assert_eq!(m.arch, "mamba");
    let spec = full_spec(m);
    let mut ctx = Ctx::new(&format!("{}-prefill-t{t}", m.name), &spec);
    let tokens = ctx.g.input_i32("tokens", vec![t]);
    let emb = ctx.w("emb");
    let mut x = ctx.g.gather(emb, tokens, "embed");
    for j in 0..m.n_layers {
        let norm_w = ctx.w(&format!("l{j}.norm_w"));
        let xn = ctx.g.rmsnorm(x, norm_w, &format!("l{j}.norm"));
        let y = block_prefill(&mut ctx, m, j, xn, t);
        x = ctx.g.add(x, y, &format!("l{j}.residual"));
    }
    let fw = ctx.w("final_norm_w");
    let x = ctx.g.rmsnorm(x, fw, "final_norm");
    let emb_t = ctx.g.transpose(emb, vec![1, 0], "lm_head.wT");
    let logits = ctx.g.matmul(x, emb_t, "lm_head.mm");
    ctx.g.output(logits);
    ctx.g
}

/// Serving prefill graph: tokens (T,) i32 -> last-position logits (1, V)
/// plus per-layer decode-ready recurrent state. Output order matches
/// [`build_decode_batched`]: logits, then per layer `conv_state{j}`
/// (K-1, d_inner) and `ssm_state{j}` (d_inner, d_state).
///
/// Requires `t >= d_conv - 1` so the conv state can be sliced off the
/// prefill window.
pub fn build_prefill_serve(m: &ModelShape, t: usize) -> Graph {
    assert_eq!(m.arch, "mamba");
    let k = m.d_conv;
    assert!(t >= k - 1, "serve prefill window {t} shorter than conv state {}", k - 1);
    super::serve::lm_serve_scaffold(
        &format!("{}-serve-prefill-t{t}", m.name),
        m,
        t,
        |ctx, j, xn| {
            let (y, conv_seq, h_last) = block_prefill_with_state(ctx, m, j, xn, t);
            let conv_state = ctx.g.slice(
                conv_seq,
                0,
                t - (k - 1),
                k - 1,
                &format!("l{j}.conv.state"),
            );
            (y, (conv_state, h_last))
        },
    )
}

/// Resume serving prefill: tokens (T,) i32 + per-layer `(conv_state,
/// ssm_state)` inputs → last-position logits (1, V) + new states, the
/// same output layout as [`build_prefill_serve`]. Valid for any
/// `t >= 1` — the carried conv tail completes every window, so there is
/// no `t >= K-1` floor like the from-scratch prefill has.
pub fn build_prefill_serve_resume(m: &ModelShape, t: usize) -> Graph {
    assert_eq!(m.arch, "mamba");
    let conv_shape = vec![m.d_conv - 1, m.d_inner()];
    let ssm_shape = vec![m.d_inner(), m.d_state];
    super::serve::lm_serve_scaffold_resume(
        &format!("{}-serve-resume-t{t}", m.name),
        m,
        t,
        &conv_shape,
        &ssm_shape,
        |ctx, j, xn, conv_in, ssm_in| {
            let (y, new_conv, h_last) =
                block_prefill_resume_with_state(ctx, m, j, xn, t, conv_in, ssm_in);
            (y, (new_conv, h_last))
        },
    )
}

/// Batched serving prefill for prefill bucket `b`: tokens (b, T) i32 →
/// logits (b, V) + per-layer batch-stacked decode states. True-batch:
/// one (b, T)-shaped node per op via
/// [`block_prefill_batched_with_state`], per-sequence bitwise identical
/// to [`build_prefill_serve`] (see `serve::lm_serve_scaffold_batched`
/// for the batching invariants).
pub fn build_prefill_serve_batched(m: &ModelShape, b: usize, t: usize) -> Graph {
    assert_eq!(m.arch, "mamba");
    let k = m.d_conv;
    assert!(t >= k - 1, "serve prefill window {t} shorter than conv state {}", k - 1);
    super::serve::lm_serve_scaffold_batched(
        &format!("{}-serve-prefill-b{b}-t{t}", m.name),
        m,
        b,
        t,
        |ctx, j, xn| {
            let (y, conv_seq, h_last) =
                block_prefill_batched_with_state(ctx, m, j, xn, b, t);
            let conv_state = ctx.g.slice(
                conv_seq,
                1,
                t - (k - 1),
                k - 1,
                &format!("l{j}.conv.state"),
            ); // (b, K-1, di)
            (y, (conv_state, h_last))
        },
    )
}

/// Replicated batched serving prefill: each sequence runs its own copy
/// of [`build_prefill_serve`], stitched together by layout ops only. The
/// coordinator routes i8 serving here — dynamic per-tensor requantize
/// scales inside a true-batch node would couple co-batched sequences.
pub fn build_prefill_serve_batched_replicated(m: &ModelShape, b: usize, t: usize) -> Graph {
    assert_eq!(m.arch, "mamba");
    let k = m.d_conv;
    assert!(t >= k - 1, "serve prefill window {t} shorter than conv state {}", k - 1);
    super::serve::lm_serve_scaffold_batched_replicated(
        &format!("{}-serve-prefill-rep-b{b}-t{t}", m.name),
        m,
        b,
        t,
        |ctx, j, xn| {
            let (y, conv_seq, h_last) = block_prefill_with_state(ctx, m, j, xn, t);
            let conv_state = ctx.g.slice(
                conv_seq,
                0,
                t - (k - 1),
                k - 1,
                &format!("l{j}.conv.state"),
            );
            (y, (conv_state, h_last))
        },
    )
}

/// Single Mamba-1 block graph over (T, d_model) — the Fig-1 / Fig-4(c)
/// profiling workload. Inputs: block params (block_spec order), then `x`.
pub fn build_block(m: &ModelShape, t: usize) -> Graph {
    assert_eq!(m.arch, "mamba");
    let spec = super::params::block_spec(m);
    let mut ctx = Ctx::new(&format!("{}-block-t{t}", m.name), &spec);
    let x = ctx.g.input("x", vec![t, m.d_model]);
    let y = block_prefill(&mut ctx, m, 0, x, t);
    ctx.g.output(y);
    ctx.g
}

/// Single-token decode-step graph: token (1,) i32 + per-layer states ->
/// logits (1, V) + new states. Used by the KPI (Tokens/s) simulation.
///
/// Inputs: params, token, then per layer `conv_state{j}` (K-1, C) and
/// `ssm_state{j}` (d_inner, N). Outputs: logits, then per-layer states in
/// the same order.
pub fn build_decode(m: &ModelShape) -> Graph {
    assert_eq!(m.arch, "mamba");
    let spec = full_spec(m);
    let mut ctx = Ctx::new(&format!("{}-decode", m.name), &spec);
    let token = ctx.g.input_i32("token", vec![1]);
    let (di, n, k) = (m.d_inner(), m.d_state, m.d_conv);
    let mut conv_states = Vec::new();
    let mut ssm_states = Vec::new();
    for j in 0..m.n_layers {
        conv_states.push(ctx.g.input(&format!("conv_state{j}"), vec![k - 1, di]));
        ssm_states.push(ctx.g.input(&format!("ssm_state{j}"), vec![di, n]));
    }

    let emb = ctx.w("emb");
    let mut x = ctx.g.gather(emb, token, "embed"); // (1, d)
    let mut out_states = Vec::new();
    for j in 0..m.n_layers {
        let nm = |s: &str| format!("l{j}.{s}");
        let norm_w = ctx.w(&nm("norm_w"));
        let xn = ctx.g.rmsnorm(x, norm_w, &nm("norm"));
        let in_proj = ctx.w(&nm("in_proj"));
        let xz = ctx.g.matmul(xn, in_proj, &nm("in_proj.mm"));
        let xi = ctx.g.slice(xz, 1, 0, di, &nm("split.x"));
        let z = ctx.g.slice(xz, 1, di, di, &nm("split.z"));

        // conv step: window = [state; x_t], dot with taps
        let window = ctx.g.concat(&[conv_states[j], xi], 0, &nm("conv.win")); // (K, di)
        let cw = ctx.w(&nm("conv_w"));
        let prod = ctx.g.mul(window, cw, &nm("conv.prod"));
        let xc = ctx.g.reduce_sum(prod, 0, &nm("conv.sum")); // (di,)
        let cb = ctx.w(&nm("conv_b"));
        let xc = ctx.g.add(xc, cb, &nm("conv.bias"));
        let xc = ctx.g.reshape(xc, vec![1, di], &nm("conv.row"));
        let xc = ctx.g.silu(xc, &nm("conv.silu"));
        let new_conv = ctx.g.slice(window, 0, 1, k - 1, &nm("conv.state"));

        let xp = ctx.w(&nm("x_proj"));
        let xdbc = ctx.g.matmul(xc, xp, &nm("x_proj.mm"));
        let r = m.resolved_dt_rank();
        let dt_r = ctx.g.slice(xdbc, 1, 0, r, &nm("split.dt"));
        let b_t = ctx.g.slice(xdbc, 1, r, n, &nm("split.B"));
        let c_t = ctx.g.slice(xdbc, 1, r + n, n, &nm("split.C"));
        let dtw = ctx.w(&nm("dt_proj_w"));
        let dtb = ctx.w(&nm("dt_proj_b"));
        let dt_f = ctx.g.matmul(dt_r, dtw, &nm("dt_proj.mm"));
        let dt_f = ctx.g.add(dt_f, dtb, &nm("dt_proj.bias"));
        let dt = ctx.g.softplus(dt_f, &nm("dt.softplus")); // (1, di)

        let a_log = ctx.w(&nm("a_log"));
        let a_exp = ctx.g.exp(a_log, &nm("A.exp"));
        let neg1 = ctx.g.const_scalar(&nm("A.neg1"), -1.0);
        let a = ctx.g.mul(a_exp, neg1, &nm("A"));

        let dt_col = ctx.g.reshape(dt, vec![di, 1], &nm("dt.col"));
        let da = ctx.g.mul(dt_col, a, &nm("dtA"));
        let da = ctx.g.exp(da, &nm("decay"));
        let xdt = ctx.g.mul(dt, xc, &nm("x.dt"));
        let xdt_col = ctx.g.reshape(xdt, vec![di, 1], &nm("x.dt.col"));
        let inflow = ctx.g.mul(xdt_col, b_t, &nm("inflow"));
        let decayed = ctx.g.mul(da, ssm_states[j], &nm("h.decay"));
        let h_new = ctx.g.add(decayed, inflow, &nm("h"));
        let c_col = ctx.g.reshape(c_t, vec![n, 1], &nm("C.col"));
        let y_t = ctx.g.matmul(h_new, c_col, &nm("y.mm"));
        let y_row = ctx.g.reshape(y_t, vec![1, di], &nm("y.row"));
        let d_skip = ctx.w(&nm("d_skip"));
        let skip = ctx.g.mul(xc, d_skip, &nm("y.skip"));
        let y = ctx.g.add(y_row, skip, &nm("y"));

        let zg = ctx.g.silu(z, &nm("gate.silu"));
        let y = ctx.g.mul(y, zg, &nm("gate.mul"));
        let op = ctx.w(&nm("out_proj"));
        let y = ctx.g.matmul(y, op, &nm("out_proj.mm"));
        x = ctx.g.add(x, y, &nm("residual"));
        out_states.push((new_conv, h_new));
    }
    let fw = ctx.w("final_norm_w");
    let x = ctx.g.rmsnorm(x, fw, "final_norm");
    let emb_t = ctx.g.transpose(emb, vec![1, 0], "lm_head.wT");
    let logits = ctx.g.matmul(x, emb_t, "lm_head.mm");
    ctx.g.output(logits);
    for (cs, ss) in out_states {
        ctx.g.output(cs);
        ctx.g.output(ss);
    }
    ctx.g
}

/// Batched decode-step graph for a fixed batch bucket `b`: tokens (b,)
/// i32 + per-layer stacked states -> logits (b, V) + new states. This is
/// the serving hot path of the planned backend — one compiled plan per
/// bucket, reused for every step.
///
/// Inputs: params, tokens, then per layer `conv_state{j}` (b, K-1, C)
/// and `ssm_state{j}` (b, d_inner, N). Outputs: logits, then per-layer
/// states in the same order. Every kernel in the graph treats the batch
/// dimension independently, so per-sequence results are bitwise
/// identical across bucket sizes (the pool leans on this to shard a
/// bucket across workers).
pub fn build_decode_batched(m: &ModelShape, b: usize) -> Graph {
    assert_eq!(m.arch, "mamba");
    assert!(b >= 1, "decode bucket must be >= 1");
    let spec = full_spec(m);
    let mut ctx = Ctx::new(&format!("{}-decode-b{b}", m.name), &spec);
    let tokens = ctx.g.input_i32("tokens", vec![b]);
    let (di, n, k) = (m.d_inner(), m.d_state, m.d_conv);
    let r = m.resolved_dt_rank();
    let mut conv_states = Vec::new();
    let mut ssm_states = Vec::new();
    for j in 0..m.n_layers {
        conv_states.push(ctx.g.input(&format!("conv_state{j}"), vec![b, k - 1, di]));
        ssm_states.push(ctx.g.input(&format!("ssm_state{j}"), vec![b, di, n]));
    }

    let emb = ctx.w("emb");
    let mut x = ctx.g.gather(emb, tokens, "embed"); // (b, d)
    let mut out_states = Vec::new();
    for j in 0..m.n_layers {
        let nm = |s: &str| format!("l{j}.{s}");
        let norm_w = ctx.w(&nm("norm_w"));
        let xn = ctx.g.rmsnorm(x, norm_w, &nm("norm"));
        let in_proj = ctx.w(&nm("in_proj"));
        let xz = ctx.g.matmul(xn, in_proj, &nm("in_proj.mm")); // (b, 2di)
        let xi = ctx.g.slice(xz, 1, 0, di, &nm("split.x"));
        let z = ctx.g.slice(xz, 1, di, di, &nm("split.z"));

        // conv step: window = [state; x_t] along time, dot with taps
        let xi_row = ctx.g.reshape(xi, vec![b, 1, di], &nm("conv.xrow"));
        let window = ctx.g.concat(&[conv_states[j], xi_row], 1, &nm("conv.win")); // (b, K, di)
        let cw = ctx.w(&nm("conv_w"));
        let prod = ctx.g.mul(window, cw, &nm("conv.prod"));
        let xc = ctx.g.reduce_sum(prod, 1, &nm("conv.sum")); // (b, di)
        let cb = ctx.w(&nm("conv_b"));
        let xc = ctx.g.add(xc, cb, &nm("conv.bias"));
        let xc = ctx.g.silu(xc, &nm("conv.silu"));
        let new_conv = ctx.g.slice(window, 1, 1, k - 1, &nm("conv.state"));

        let xp = ctx.w(&nm("x_proj"));
        let xdbc = ctx.g.matmul(xc, xp, &nm("x_proj.mm")); // (b, r+2n)
        let dt_r = ctx.g.slice(xdbc, 1, 0, r, &nm("split.dt"));
        let b_t = ctx.g.slice(xdbc, 1, r, n, &nm("split.B"));
        let c_t = ctx.g.slice(xdbc, 1, r + n, n, &nm("split.C"));
        let dtw = ctx.w(&nm("dt_proj_w"));
        let dtb = ctx.w(&nm("dt_proj_b"));
        let dt_f = ctx.g.matmul(dt_r, dtw, &nm("dt_proj.mm"));
        let dt_f = ctx.g.add(dt_f, dtb, &nm("dt_proj.bias"));
        let dt = ctx.g.softplus(dt_f, &nm("dt.softplus")); // (b, di)

        let a_log = ctx.w(&nm("a_log"));
        let a_exp = ctx.g.exp(a_log, &nm("A.exp"));
        let neg1 = ctx.g.const_scalar(&nm("A.neg1"), -1.0);
        let a = ctx.g.mul(a_exp, neg1, &nm("A")); // (di, n)

        let dt_col = ctx.g.reshape(dt, vec![b, di, 1], &nm("dt.col"));
        let da = ctx.g.mul(dt_col, a, &nm("dtA")); // (b, di, n)
        let da = ctx.g.exp(da, &nm("decay"));
        let xdt = ctx.g.mul(dt, xc, &nm("x.dt")); // (b, di)
        let xdt_col = ctx.g.reshape(xdt, vec![b, di, 1], &nm("x.dt.col"));
        let b_row = ctx.g.reshape(b_t, vec![b, 1, n], &nm("B.row"));
        let inflow = ctx.g.mul(xdt_col, b_row, &nm("inflow")); // (b, di, n)
        let decayed = ctx.g.mul(da, ssm_states[j], &nm("h.decay"));
        let h_new = ctx.g.add(decayed, inflow, &nm("h")); // (b, di, n)
        let c_col = ctx.g.reshape(c_t, vec![b, n, 1], &nm("C.col"));
        let y_t = ctx.g.matmul(h_new, c_col, &nm("y.mm")); // (b, di, 1)
        let y_row = ctx.g.reshape(y_t, vec![b, di], &nm("y.row"));
        let d_skip = ctx.w(&nm("d_skip"));
        let skip = ctx.g.mul(xc, d_skip, &nm("y.skip"));
        let y = ctx.g.add(y_row, skip, &nm("y"));

        let zg = ctx.g.silu(z, &nm("gate.silu"));
        let y = ctx.g.mul(y, zg, &nm("gate.mul"));
        let op = ctx.w(&nm("out_proj"));
        let y = ctx.g.matmul(y, op, &nm("out_proj.mm"));
        x = ctx.g.add(x, y, &nm("residual"));
        out_states.push((new_conv, h_new));
    }
    let fw = ctx.w("final_norm_w");
    let x = ctx.g.rmsnorm(x, fw, "final_norm");
    let emb_t = ctx.g.transpose(emb, vec![1, 0], "lm_head.wT");
    let logits = ctx.g.matmul(x, emb_t, "lm_head.mm"); // (b, V)
    ctx.g.output(logits);
    for (cs, ss) in out_states {
        ctx.g.output(cs);
        ctx.g.output(ss);
    }
    ctx.g
}

/// Speculative-verify graph: tokens (b, kw) i32 + per-layer stacked
/// states -> logits at ALL kw positions (b, kw, V) + states advanced by
/// kw steps. One compiled plan per (bucket, window); the scheduler uses
/// it to score a drafted window in a single multi-token step.
///
/// Bitwise contract: this graph is [`build_decode_batched`] unrolled kw
/// times — position-independent stages (projections, dt pipeline, gate,
/// norms) run batched over a (b, kw, ·) axis, which every kernel treats
/// row-independently, while the conv window and the scan recurrence
/// replay decode's exact per-step op sequence. Position p's logits and
/// the final states are therefore bitwise identical to kw sequential
/// decode steps, at f32 and f16 alike (fused chains round per stage).
/// i8 is excluded: its dynamic per-tensor activation scales would couple
/// the kw positions inside one node.
pub fn build_verify_batched(m: &ModelShape, b: usize, kw: usize) -> Graph {
    assert_eq!(m.arch, "mamba");
    assert!(b >= 1, "verify bucket must be >= 1");
    assert!(kw >= 1, "verify window must be >= 1");
    let spec = full_spec(m);
    let mut ctx = Ctx::new(&format!("{}-verify-b{b}-k{kw}", m.name), &spec);
    let tokens = ctx.g.input_i32("tokens", vec![b, kw]);
    let (di, n, k) = (m.d_inner(), m.d_state, m.d_conv);
    let r = m.resolved_dt_rank();
    let mut conv_states = Vec::new();
    let mut ssm_states = Vec::new();
    for j in 0..m.n_layers {
        conv_states.push(ctx.g.input(&format!("conv_state{j}"), vec![b, k - 1, di]));
        ssm_states.push(ctx.g.input(&format!("ssm_state{j}"), vec![b, di, n]));
    }

    let emb = ctx.w("emb");
    let tok_flat = ctx.g.reshape(tokens, vec![b * kw], "tokens.flat");
    let rows = ctx.g.gather(emb, tok_flat, "embed"); // (b*kw, d)
    let mut x = ctx.g.reshape(rows, vec![b, kw, m.d_model], "embed.batch");
    let mut out_states = Vec::new();
    for j in 0..m.n_layers {
        let nm = |s: &str| format!("l{j}.{s}");
        let norm_w = ctx.w(&nm("norm_w"));
        let xn = ctx.g.rmsnorm(x, norm_w, &nm("norm"));
        let in_proj = ctx.w(&nm("in_proj"));
        let xz = ctx.g.matmul(xn, in_proj, &nm("in_proj.mm")); // (b, kw, 2di)
        let xi = ctx.g.slice(xz, 2, 0, di, &nm("split.x"));
        let z = ctx.g.slice(xz, 2, di, di, &nm("split.z"));

        // conv: extend the state with the kw raw rows, then each position
        // dots decode's exact (b, K, di) window against the taps
        let ext = ctx.g.concat(&[conv_states[j], xi], 1, &nm("conv.ext")); // (b, K-1+kw, di)
        let cw = ctx.w(&nm("conv_w"));
        let mut xc_rows = Vec::with_capacity(kw);
        for p in 0..kw {
            let pn = |s: &str| format!("l{j}.p{p}.{s}");
            let win = ctx.g.slice(ext, 1, p, k, &pn("conv.win")); // (b, K, di)
            let prod = ctx.g.mul(win, cw, &pn("conv.prod"));
            let sum = ctx.g.reduce_sum(prod, 1, &pn("conv.sum")); // (b, di)
            xc_rows.push(ctx.g.reshape(sum, vec![b, 1, di], &pn("conv.row")));
        }
        let xc = ctx.g.concat(&xc_rows, 1, &nm("conv.taps")); // (b, kw, di)
        let cb = ctx.w(&nm("conv_b"));
        let xc = ctx.g.add(xc, cb, &nm("conv.bias"));
        let xc = ctx.g.silu(xc, &nm("conv.silu"));
        let new_conv = ctx.g.slice(ext, 1, kw, k - 1, &nm("conv.state"));

        let xp = ctx.w(&nm("x_proj"));
        let xdbc = ctx.g.matmul(xc, xp, &nm("x_proj.mm")); // (b, kw, r+2n)
        let dt_r = ctx.g.slice(xdbc, 2, 0, r, &nm("split.dt"));
        let b_t = ctx.g.slice(xdbc, 2, r, n, &nm("split.B"));
        let c_t = ctx.g.slice(xdbc, 2, r + n, n, &nm("split.C"));
        let dtw = ctx.w(&nm("dt_proj_w"));
        let dtb = ctx.w(&nm("dt_proj_b"));
        let dt_f = ctx.g.matmul(dt_r, dtw, &nm("dt_proj.mm"));
        let dt_f = ctx.g.add(dt_f, dtb, &nm("dt_proj.bias"));
        let dt = ctx.g.softplus(dt_f, &nm("dt.softplus")); // (b, kw, di)

        let a_log = ctx.w(&nm("a_log"));
        let a_exp = ctx.g.exp(a_log, &nm("A.exp"));
        let neg1 = ctx.g.const_scalar(&nm("A.neg1"), -1.0);
        let a = ctx.g.mul(a_exp, neg1, &nm("A")); // (di, n)

        // position-independent scan operands, batched over kw
        let dt_col = ctx.g.reshape(dt, vec![b, kw, di, 1], &nm("dt.col"));
        let da = ctx.g.mul(dt_col, a, &nm("dtA")); // (b, kw, di, n)
        let da = ctx.g.exp(da, &nm("decay"));
        let xdt = ctx.g.mul(dt, xc, &nm("x.dt")); // (b, kw, di)
        let xdt_col = ctx.g.reshape(xdt, vec![b, kw, di, 1], &nm("x.dt.col"));
        let b_row = ctx.g.reshape(b_t, vec![b, kw, 1, n], &nm("B.row"));
        let inflow = ctx.g.mul(xdt_col, b_row, &nm("inflow")); // (b, kw, di, n)

        // the recurrence itself replays decode's step ops sequentially
        let mut h = ssm_states[j];
        let mut y_rows = Vec::with_capacity(kw);
        for p in 0..kw {
            let pn = |s: &str| format!("l{j}.p{p}.{s}");
            let da_s = ctx.g.slice(da, 1, p, 1, &pn("decay.s"));
            let da_p = ctx.g.reshape(da_s, vec![b, di, n], &pn("decay.p"));
            let in_s = ctx.g.slice(inflow, 1, p, 1, &pn("inflow.s"));
            let in_p = ctx.g.reshape(in_s, vec![b, di, n], &pn("inflow.p"));
            let decayed = ctx.g.mul(da_p, h, &pn("h.decay"));
            h = ctx.g.add(decayed, in_p, &pn("h")); // (b, di, n)
            let c_s = ctx.g.slice(c_t, 1, p, 1, &pn("C.s"));
            let c_col = ctx.g.reshape(c_s, vec![b, n, 1], &pn("C.col"));
            let y_t = ctx.g.matmul(h, c_col, &pn("y.mm")); // (b, di, 1)
            y_rows.push(ctx.g.reshape(y_t, vec![b, 1, di], &pn("y.row")));
        }
        let y_mm = ctx.g.concat(&y_rows, 1, &nm("y.cat")); // (b, kw, di)
        let d_skip = ctx.w(&nm("d_skip"));
        let skip = ctx.g.mul(xc, d_skip, &nm("y.skip"));
        let y = ctx.g.add(y_mm, skip, &nm("y"));

        let zg = ctx.g.silu(z, &nm("gate.silu"));
        let y = ctx.g.mul(y, zg, &nm("gate.mul"));
        let op = ctx.w(&nm("out_proj"));
        let y = ctx.g.matmul(y, op, &nm("out_proj.mm"));
        x = ctx.g.add(x, y, &nm("residual"));
        out_states.push((new_conv, h));
    }
    let fw = ctx.w("final_norm_w");
    let x = ctx.g.rmsnorm(x, fw, "final_norm");
    let emb_t = ctx.g.transpose(emb, vec![1, 0], "lm_head.wT");
    let logits = ctx.g.matmul(x, emb_t, "lm_head.mm"); // (b, kw, V)
    ctx.g.output(logits);
    for (cs, ss) in out_states {
        ctx.g.output(cs);
        ctx.g.output(ss);
    }
    ctx.g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::graph::Census;

    #[test]
    fn prefill_graph_builds_with_expected_io() {
        let m = presets::tiny_mamba();
        let g = build_prefill(&m, 8);
        // params + tokens
        assert_eq!(g.inputs.len(), full_spec(&m).entries.len() + 1);
        assert_eq!(g.outputs.len(), 1);
        assert_eq!(g.shape(g.outputs[0]), &[8, 256]);
    }

    #[test]
    fn block_census_shows_mamba1_signature() {
        // staged projections: >= 4 MatMuls, both bottleneck activations,
        // NO CumSum/ReduceSum (appendix A.1 operator contrast)
        let m = presets::block130m_mamba();
        let g = build_block(&m, 4);
        let c = Census::of(&g);
        assert!(c.get("MatMul") >= 4, "matmuls: {}", c.get("MatMul"));
        assert!(c.get("Swish") >= 2);
        assert!(c.get("SoftPlus") >= 1);
        assert_eq!(c.get("CumSum"), 0);
        assert_eq!(c.get("ReduceSum"), 0);
    }

    #[test]
    fn decode_graph_outputs_states() {
        let m = presets::tiny_mamba();
        let g = build_decode(&m);
        // logits + 2 states per layer
        assert_eq!(g.outputs.len(), 1 + 2 * m.n_layers);
        assert_eq!(g.shape(g.outputs[0]), &[1, m.vocab_size]);
        assert_eq!(g.shape(g.outputs[1]), &[m.d_conv - 1, m.d_inner()]);
        assert_eq!(g.shape(g.outputs[2]), &[m.d_inner(), m.d_state]);
    }

    #[test]
    fn serve_prefill_outputs_last_logits_and_states() {
        let m = presets::tiny_mamba();
        let g = build_prefill_serve(&m, 8);
        assert_eq!(g.outputs.len(), 1 + 2 * m.n_layers);
        assert_eq!(g.shape(g.outputs[0]), &[1, m.vocab_size]);
        assert_eq!(g.shape(g.outputs[1]), &[m.d_conv - 1, m.d_inner()]);
        assert_eq!(g.shape(g.outputs[2]), &[m.d_inner(), m.d_state]);
    }

    #[test]
    fn batched_prefill_io_shapes() {
        let m = presets::tiny_mamba();
        let (b, t) = (2usize, 8usize);
        let g = build_prefill_serve_batched(&m, b, t);
        // params + the (b, t) token matrix
        assert_eq!(g.inputs.len(), full_spec(&m).entries.len() + 1);
        assert_eq!(g.outputs.len(), 1 + 2 * m.n_layers);
        assert_eq!(g.shape(g.outputs[0]), &[b, m.vocab_size]);
        assert_eq!(g.shape(g.outputs[1]), &[b, m.d_conv - 1, m.d_inner()]);
        assert_eq!(g.shape(g.outputs[2]), &[b, m.d_inner(), m.d_state]);
    }

    #[test]
    fn batched_decode_io_shapes() {
        let m = presets::tiny_mamba();
        let b = 4;
        let g = build_decode_batched(&m, b);
        // params + tokens + 2 states per layer
        assert_eq!(g.inputs.len(), full_spec(&m).entries.len() + 1 + 2 * m.n_layers);
        assert_eq!(g.outputs.len(), 1 + 2 * m.n_layers);
        assert_eq!(g.shape(g.outputs[0]), &[b, m.vocab_size]);
        assert_eq!(g.shape(g.outputs[1]), &[b, m.d_conv - 1, m.d_inner()]);
        assert_eq!(g.shape(g.outputs[2]), &[b, m.d_inner(), m.d_state]);
    }

    #[test]
    fn batched_decode_is_bitwise_per_sequence() {
        // a b=2 batch must reproduce the two b=1 runs exactly
        use crate::exec::run_once;
        use crate::graph::Tensor;
        use crate::quality::param_inputs;

        let m = presets::tiny_mamba();
        let spec = full_spec(&m);
        let mut rng = crate::util::Prng::new(11);
        let weights = rng.range_vec(spec.total(), -0.1, 0.1);
        let params = param_inputs(&spec, &weights);
        let (di, n, k) = (m.d_inner(), m.d_state, m.d_conv);
        let state_f = |seed: u64, len: usize| {
            let mut r = crate::util::Prng::new(seed);
            r.range_vec(len, -0.5, 0.5)
        };

        let conv_seed = |s: usize, j: usize| 1000 + 100 * s as u64 + j as u64;
        let ssm_seed = |s: usize, j: usize| 2000 + 100 * s as u64 + j as u64;

        let g1 = build_decode_batched(&m, 1);
        let g2 = build_decode_batched(&m, 2);
        let mut singles = Vec::new();
        for s in 0..2usize {
            let mut inputs = params.clone();
            inputs.push(Tensor::i32(vec![1], vec![40 + s as i32]));
            for j in 0..m.n_layers {
                inputs.push(Tensor::f32(
                    vec![1, k - 1, di],
                    state_f(conv_seed(s, j), (k - 1) * di),
                ));
                inputs.push(Tensor::f32(
                    vec![1, di, n],
                    state_f(ssm_seed(s, j), di * n),
                ));
            }
            singles.push(run_once(&g1, &inputs).expect("b=1 decode"));
        }
        let mut inputs = params.clone();
        inputs.push(Tensor::i32(vec![2], vec![40, 41]));
        for j in 0..m.n_layers {
            let mut conv = Vec::new();
            let mut ssm = Vec::new();
            for s in 0..2usize {
                conv.extend(state_f(conv_seed(s, j), (k - 1) * di));
                ssm.extend(state_f(ssm_seed(s, j), di * n));
            }
            inputs.push(Tensor::f32(vec![2, k - 1, di], conv));
            inputs.push(Tensor::f32(vec![2, di, n], ssm));
        }
        let batched = run_once(&g2, &inputs).expect("b=2 decode");
        let v = m.vocab_size;
        for s in 0..2 {
            assert_eq!(
                &batched[0].as_f32()[s * v..(s + 1) * v],
                singles[s][0].as_f32(),
                "logits diverge for sequence {s}"
            );
            for j in 0..m.n_layers {
                let cl = (k - 1) * di;
                assert_eq!(
                    &batched[1 + 2 * j].as_f32()[s * cl..(s + 1) * cl],
                    singles[s][1 + 2 * j].as_f32(),
                    "conv state diverges (seq {s}, layer {j})"
                );
                let sl = di * n;
                assert_eq!(
                    &batched[2 + 2 * j].as_f32()[s * sl..(s + 1) * sl],
                    singles[s][2 + 2 * j].as_f32(),
                    "ssm state diverges (seq {s}, layer {j})"
                );
            }
        }
    }

    #[test]
    fn resume_continues_monolithic_prefill_bitwise() {
        // prefill the first `split` tokens from scratch, feed the
        // resulting state into the resume graph for the rest — logits and
        // final states must match the monolithic prefill bit for bit
        use crate::exec::run_once;
        use crate::graph::Tensor;
        use crate::quality::param_inputs;

        let m = presets::tiny_mamba();
        let spec = full_spec(&m);
        let mut rng = crate::util::Prng::new(7);
        let weights = rng.range_vec(spec.total(), -0.1, 0.1);
        let params = param_inputs(&spec, &weights);
        let total = 11usize;
        let tokens: Vec<i32> = (0..total as i32).map(|i| 3 + (i * 7) % 50).collect();

        let run = |g: &Graph, extra: Vec<Tensor>| {
            let mut inputs = params.clone();
            inputs.extend(extra);
            run_once(g, &inputs).expect("run")
        };
        let g_full = build_prefill_serve(&m, total);
        let full = run(&g_full, vec![Tensor::i32(vec![total], tokens.clone())]);
        // any split works for mamba-1 (resume grain 1); try several,
        // including one that leaves a single-token remainder
        for split in [2usize, 6, 10] {
            let g_head = build_prefill_serve(&m, split);
            let head = run(
                &g_head,
                vec![Tensor::i32(vec![split], tokens[..split].to_vec())],
            );
            let rest = total - split;
            let g_res = build_prefill_serve_resume(&m, rest);
            let mut extra = vec![Tensor::i32(vec![rest], tokens[split..].to_vec())];
            for j in 0..m.n_layers {
                extra.push(head[1 + 2 * j].clone());
                extra.push(head[2 + 2 * j].clone());
            }
            let res = run(&g_res, extra);
            for (i, (a, b)) in full.iter().zip(res.iter()).enumerate() {
                assert_eq!(a.as_f32(), b.as_f32(), "split {split}: output {i} diverges");
            }
        }
    }

    #[test]
    fn scan_unrolls_linearly_with_t() {
        let m = presets::tiny_mamba();
        let a = build_block_nodes(&m, 4);
        let b = build_block_nodes(&m, 8);
        assert!(b > a + 4 * 10, "t=4: {a} nodes, t=8: {b} nodes");
    }

    fn build_block_nodes(m: &ModelShape, t: usize) -> usize {
        build_block(m, t).live_count()
    }
}
