//! Mamba-2 model as an IR graph (mirror of `python/compile/mamba2.py`).
//!
//! The SSD layer is built exactly the way a static conversion lowers the
//! official chunked implementation (Listing 1 of Dao & Gu 2024):
//!
//! * the sequence is right-padded to a multiple of `chunk` — this is why
//!   the paper's T=4 Mamba-2 130M graph still contains a 256x256 CumSum:
//!   the segsum runs at chunk resolution regardless of real tokens;
//! * segsum = broadcast -> tril(-1) mask -> **CumSum over a (H, Tc, Tc)
//!   tensor along axis -2** — this node is `CumSum_b` (>99.9 % of
//!   Mamba-2's CumSum time per paper §2.1);
//! * the C·B^T attention-like contraction is lowered as broadcast-Mul +
//!   **ReduceSum** (the einsum decomposition ONNX produces for >2-operand
//!   einsums) — these are the ReduceSum bottlenecks ReduBA targets.

use crate::config::ModelShape;
use crate::graph::{Graph, NodeId};

use super::mamba1::Ctx;
use super::params::{block_spec, full_spec};

/// SSD over one chunk. `xh` (H, Tc, P); `dt_h` (H, Tc); `a` (H, 1);
/// `b`/`c` (Tc, N); `h0` (H, P, N) or None. Returns (y (H, Tc, P), state).
#[allow(clippy::too_many_arguments)]
fn ssd_chunk(
    ctx: &mut Ctx,
    nm: &dyn Fn(&str) -> String,
    tc: usize,
    h: usize,
    _p: usize,
    n: usize,
    xh: NodeId,
    dt_h: NodeId,
    a: NodeId,
    b: NodeId,
    c: NodeId,
    h0: Option<NodeId>,
) -> (NodeId, NodeId) {

    // da = dt * a : (H, Tc)
    let da = ctx.g.mul(dt_h, a, &nm("da"));

    // --- segsum: broadcast -> strict-tril mask -> CumSum_b --------------
    let da_col = ctx.g.reshape(da, vec![h, tc, 1], &nm("segsum.col"));
    let da_rep = ctx.g.broadcast(da_col, vec![h, tc, tc], &nm("segsum.rep"));
    let tril_m1 = ctx.g.const_tril_offset(&nm("segsum.mask"), tc, -1);
    let masked = ctx.g.mul(da_rep, tril_m1, &nm("segsum.masked"));
    // CumSum_b: (H, Tc, Tc) along axis -2 — the paper's 256x256 bottleneck
    let seg = ctx.g.cumsum(masked, 1, &nm("segsum.cumsum_b"));
    let seg_exp = ctx.g.exp(seg, &nm("L.exp"));
    let tril0 = ctx.g.const_tril(&nm("L.mask"), tc);
    let l_mat = ctx.g.mul(seg_exp, tril0, &nm("L")); // (H, Tc, Tc)

    // --- C B^T via broadcast-Mul + ReduceSum (einsum decomposition) -----
    let c_row = ctx.g.reshape(c, vec![tc, 1, n], &nm("cb.c"));
    let b_row = ctx.g.reshape(b, vec![1, tc, n], &nm("cb.b"));
    let cb_big = ctx.g.mul(c_row, b_row, &nm("cb.mul")); // (Tc, Tc, N)
    let cb = ctx.g.reduce_sum(cb_big, 2, &nm("cb.reducesum")); // (Tc, Tc)

    // scores = (C B^T) ⊙ L, then intra-chunk outputs
    let scores = ctx.g.mul(l_mat, cb, &nm("scores")); // (H, Tc, Tc)
    let dt_col = ctx.g.reshape(dt_h, vec![h, tc, 1], &nm("xdt.dt"));
    let xdt = ctx.g.mul(xh, dt_col, &nm("xdt")); // (H, Tc, P)
    let mut y = ctx.g.matmul(scores, xdt, &nm("y.diag")); // (H, Tc, P)

    // --- chunk state: decay-weighted contraction over Tc ----------------
    // da_cs (H, Tc) = cumsum(da); decay = exp(da_cs[last] - da_cs)
    let da_cs = ctx.g.cumsum(da, 1, &nm("state.cumsum"));
    let last = ctx.g.slice(da_cs, 1, tc - 1, 1, &nm("state.last")); // (H,1)
    let diff = ctx.g.sub(last, da_cs, &nm("state.diff"));
    let decay = ctx.g.exp(diff, &nm("state.decay")); // (H, Tc)
    let wgt = ctx.g.mul(decay, dt_h, &nm("state.w")); // (H, Tc)
    let w_col = ctx.g.reshape(wgt, vec![h, tc, 1], &nm("state.w.col"));
    let xw = ctx.g.mul(xh, w_col, &nm("state.xw")); // (H, Tc, P)
    let xw_t = ctx.g.transpose(xw, vec![0, 2, 1], &nm("state.xw.T")); // (H,P,Tc)
    let mut state = ctx.g.matmul(xw_t, b, &nm("state.mm")); // (H, P, N)

    // --- incoming-state contribution (steps 3/4) -------------------------
    if let Some(h0) = h0 {
        let sdo = ctx.g.exp(da_cs, &nm("off.decay")); // (H, Tc)
        let h0_t = ctx.g.transpose(h0, vec![0, 2, 1], &nm("off.h0T")); // (H,N,P)
        let y_off = ctx.g.matmul(c, h0_t, &nm("off.mm")); // (H, Tc, P)
        let sdo_col = ctx.g.reshape(sdo, vec![h, tc, 1], &nm("off.col"));
        let y_off = ctx.g.mul(y_off, sdo_col, &nm("off.scaled"));
        y = ctx.g.add(y, y_off, &nm("y.with_off"));
        let chunk_decay = ctx.g.reshape(last, vec![h, 1, 1], &nm("carry.decay"));
        let chunk_decay = ctx.g.exp(chunk_decay, &nm("carry.exp"));
        let carried = ctx.g.mul(h0, chunk_decay, &nm("carry"));
        state = ctx.g.add(state, carried, &nm("state.total"));
    }
    (y, state)
}

/// Batched SSD over one chunk: the rank-4 counterpart of [`ssd_chunk`]
/// with a leading batch dimension on every activation. `xh` (B, H, Tc,
/// P); `dt_h` (B, H, Tc); `a` (H, 1); `b`/`c` (B, Tc, N); `h0` (B, H, P,
/// N) or None. Returns (y (B, H, Tc, P), state (B, H, P, N)).
///
/// Two contractions need the per-sequence `b`/`c` aligned under the head
/// axis before a batched matmul (`matmul_shape` has no batch-dim
/// broadcast): they reshape to (B, 1, Tc, N) and broadcast to (B, H, Tc,
/// N) — an exact copy of the values the single-sequence kernel reads
/// through its `b_step = 0` operand reuse, so per-sequence results stay
/// bitwise identical.
#[allow(clippy::too_many_arguments)]
fn ssd_chunk_batched(
    ctx: &mut Ctx,
    nm: &dyn Fn(&str) -> String,
    bsz: usize,
    tc: usize,
    h: usize,
    _p: usize,
    n: usize,
    xh: NodeId,
    dt_h: NodeId,
    a: NodeId,
    b: NodeId,
    c: NodeId,
    h0: Option<NodeId>,
) -> (NodeId, NodeId) {
    // da = dt * a : (B, H, Tc)
    let da = ctx.g.mul(dt_h, a, &nm("da"));

    // --- segsum: broadcast -> strict-tril mask -> CumSum_b --------------
    let da_col = ctx.g.reshape(da, vec![bsz, h, tc, 1], &nm("segsum.col"));
    let da_rep = ctx.g.broadcast(da_col, vec![bsz, h, tc, tc], &nm("segsum.rep"));
    let tril_m1 = ctx.g.const_tril_offset(&nm("segsum.mask"), tc, -1);
    let masked = ctx.g.mul(da_rep, tril_m1, &nm("segsum.masked"));
    let seg = ctx.g.cumsum(masked, 2, &nm("segsum.cumsum_b"));
    let seg_exp = ctx.g.exp(seg, &nm("L.exp"));
    let tril0 = ctx.g.const_tril(&nm("L.mask"), tc);
    let l_mat = ctx.g.mul(seg_exp, tril0, &nm("L")); // (B, H, Tc, Tc)

    // --- C B^T via broadcast-Mul + ReduceSum (einsum decomposition) -----
    let c_row = ctx.g.reshape(c, vec![bsz, tc, 1, n], &nm("cb.c"));
    let b_row = ctx.g.reshape(b, vec![bsz, 1, tc, n], &nm("cb.b"));
    let cb_big = ctx.g.mul(c_row, b_row, &nm("cb.mul")); // (B, Tc, Tc, N)
    let cb = ctx.g.reduce_sum(cb_big, 3, &nm("cb.reducesum")); // (B, Tc, Tc)
    // align under the head axis before the broadcast against L
    let cb = ctx.g.reshape(cb, vec![bsz, 1, tc, tc], &nm("cb.rows"));

    // scores = (C B^T) ⊙ L, then intra-chunk outputs
    let scores = ctx.g.mul(l_mat, cb, &nm("scores")); // (B, H, Tc, Tc)
    let dt_col = ctx.g.reshape(dt_h, vec![bsz, h, tc, 1], &nm("xdt.dt"));
    let xdt = ctx.g.mul(xh, dt_col, &nm("xdt")); // (B, H, Tc, P)
    let mut y = ctx.g.matmul(scores, xdt, &nm("y.diag")); // (B, H, Tc, P)

    // --- chunk state: decay-weighted contraction over Tc ----------------
    let da_cs = ctx.g.cumsum(da, 2, &nm("state.cumsum")); // (B, H, Tc)
    let last = ctx.g.slice(da_cs, 2, tc - 1, 1, &nm("state.last")); // (B, H, 1)
    let diff = ctx.g.sub(last, da_cs, &nm("state.diff"));
    let decay = ctx.g.exp(diff, &nm("state.decay")); // (B, H, Tc)
    let wgt = ctx.g.mul(decay, dt_h, &nm("state.w")); // (B, H, Tc)
    let w_col = ctx.g.reshape(wgt, vec![bsz, h, tc, 1], &nm("state.w.col"));
    let xw = ctx.g.mul(xh, w_col, &nm("state.xw")); // (B, H, Tc, P)
    let xw_t = ctx.g.transpose(xw, vec![0, 1, 3, 2], &nm("state.xw.T")); // (B,H,P,Tc)
    let b_mid = ctx.g.reshape(b, vec![bsz, 1, tc, n], &nm("state.b.mid"));
    let b_bc = ctx.g.broadcast(b_mid, vec![bsz, h, tc, n], &nm("state.b.rep"));
    let mut state = ctx.g.matmul(xw_t, b_bc, &nm("state.mm")); // (B, H, P, N)

    // --- incoming-state contribution (steps 3/4) -------------------------
    if let Some(h0) = h0 {
        let sdo = ctx.g.exp(da_cs, &nm("off.decay")); // (B, H, Tc)
        let h0_t = ctx.g.transpose(h0, vec![0, 1, 3, 2], &nm("off.h0T")); // (B,H,N,P)
        let c_mid = ctx.g.reshape(c, vec![bsz, 1, tc, n], &nm("off.c.mid"));
        let c_bc = ctx.g.broadcast(c_mid, vec![bsz, h, tc, n], &nm("off.c.rep"));
        let y_off = ctx.g.matmul(c_bc, h0_t, &nm("off.mm")); // (B, H, Tc, P)
        let sdo_col = ctx.g.reshape(sdo, vec![bsz, h, tc, 1], &nm("off.col"));
        let y_off = ctx.g.mul(y_off, sdo_col, &nm("off.scaled"));
        y = ctx.g.add(y, y_off, &nm("y.with_off"));
        let chunk_decay = ctx.g.reshape(last, vec![bsz, h, 1, 1], &nm("carry.decay"));
        let chunk_decay = ctx.g.exp(chunk_decay, &nm("carry.exp"));
        let carried = ctx.g.mul(h0, chunk_decay, &nm("carry"));
        state = ctx.g.add(state, carried, &nm("state.total"));
    }
    (y, state)
}

/// One Mamba-2 block over `x` (T, d_model). `t_pad` is T padded up to a
/// chunk multiple (the conversion-time padding of the official code).
pub(crate) fn block_prefill(
    ctx: &mut Ctx,
    m: &ModelShape,
    j: usize,
    x: NodeId,
    t: usize,
) -> NodeId {
    block_prefill_with_state(ctx, m, j, x, t).0
}

/// Like `block_prefill` but also returns the final SSD state node —
/// a real output of the conversion-time prefill graph (it seeds decode),
/// so the profiling/census workloads keep the state math live.
pub(crate) fn block_prefill_with_state(
    ctx: &mut Ctx,
    m: &ModelShape,
    j: usize,
    x: NodeId,
    t: usize,
) -> (NodeId, NodeId) {
    let (out, _xbc_raw, state) = block_prefill_inner(ctx, m, j, x, t, true);
    (out, state)
}

/// One Mamba-2 block, shared by the conversion-time and serving prefill
/// builders. `pad_to_chunk` selects the sequence-length policy:
///
/// * `true` — conversion-time lowering: right-pad to a chunk multiple
///   (this is what keeps the paper's 256x256 CumSum_b in the T=4 graph)
///   and slice the pads back off the block output. The returned state is
///   the *padded* chunk state — fine for profiling/census, wrong for
///   decode (`dt` on pads is `softplus(dt_bias)` ≠ 0, so padding keeps
///   decaying the state through zero-inflow steps);
/// * `false` — serving: no padding, full chunks plus a real-length
///   remainder chunk (`ssd_chunk` is generic over the chunk length), so
///   the returned state is exactly the recurrence state after `t` real
///   tokens and continues bit-exactly into the decode graphs.
///
/// Returns `(block_out, raw pre-conv xbc sequence (T, conv_dim), ssd
/// state (H, P, N))`; the serve builder slices the decode conv state off
/// the raw xbc sequence.
fn block_prefill_inner(
    ctx: &mut Ctx,
    m: &ModelShape,
    j: usize,
    x: NodeId,
    t: usize,
    pad_to_chunk: bool,
) -> (NodeId, NodeId, NodeId) {
    let (di, n) = (m.d_inner(), m.d_state);
    let (h, p) = (m.n_heads(), m.headdim);
    let chunk = m.chunk;
    let t_eff = if pad_to_chunk { t.div_ceil(chunk) * chunk } else { t };
    let nm_s = move |j: usize, s: &str| format!("l{j}.{s}");
    let nm = |s: &str| nm_s(j, s);

    // single projection emits [z, x, B, C, dt] at once (appendix A.1)
    let in_proj = ctx.w(&nm("in_proj"));
    let zxbcdt = ctx.g.matmul(x, in_proj, &nm("in_proj.mm"));
    let z = ctx.g.slice(zxbcdt, 1, 0, di, &nm("split.z"));
    let xbc_raw = ctx.g.slice(zxbcdt, 1, di, di + 2 * n, &nm("split.xbc"));
    let dt_raw = ctx.g.slice(zxbcdt, 1, 2 * di + 2 * n, h, &nm("split.dt"));

    // conv over (x, B, C) together, then SiLU
    let (cw, cb) = (ctx.w(&nm("conv_w")), ctx.w(&nm("conv_b")));
    let xbc = ctx.g.conv1d_causal(xbc_raw, cw, cb, &nm("conv"));
    let xbc = ctx.g.silu(xbc, &nm("conv.silu"));
    let xi = ctx.g.slice(xbc, 1, 0, di, &nm("split.x"));
    let b_sel = ctx.g.slice(xbc, 1, di, n, &nm("split.B"));
    let c_sel = ctx.g.slice(xbc, 1, di + n, n, &nm("split.C"));

    // dt = softplus(dt_raw + bias) : (T, H)
    let dtb = ctx.w(&nm("dt_bias"));
    let dt = ctx.g.add(dt_raw, dtb, &nm("dt.bias"));
    let dt = ctx.g.softplus(dt, &nm("dt.softplus"));

    // a = -exp(a_log) : (H,) -> (H, 1)
    let a_log = ctx.w(&nm("a_log"));
    let a_exp = ctx.g.exp(a_log, &nm("A.exp"));
    let neg1 = ctx.g.const_scalar(&nm("A.neg1"), -1.0);
    let a = ctx.g.mul(a_exp, neg1, &nm("A"));
    let a = ctx.g.reshape(a, vec![h, 1], &nm("A.col"));

    // pad sequence dim to chunk multiple (zeros: dt rows are garbage on
    // pads but dt only multiplies x = 0 there, and y pads are sliced off)
    let pad = t_eff - t;
    let (xi, b_sel, c_sel, dt) = if pad > 0 {
        let zx = crate::graph::Tensor::zeros(vec![pad, di]);
        let zn = crate::graph::Tensor::zeros(vec![pad, n]);
        let zh = crate::graph::Tensor::zeros(vec![pad, h]);
        let px = ctx.g.constant(&nm("pad.x"), zx);
        let pb = ctx.g.constant(&nm("pad.b"), zn.clone());
        let pc = ctx.g.constant(&nm("pad.c"), zn);
        let pd = ctx.g.constant(&nm("pad.dt"), zh);
        (
            ctx.g.concat(&[xi, px], 0, &nm("pad.cat.x")),
            ctx.g.concat(&[b_sel, pb], 0, &nm("pad.cat.b")),
            ctx.g.concat(&[c_sel, pc], 0, &nm("pad.cat.c")),
            ctx.g.concat(&[dt, pd], 0, &nm("pad.cat.dt")),
        )
    } else {
        (xi, b_sel, c_sel, dt)
    };

    // head layout: (T, di) -> (H, T, P); dt -> (H, T)
    let xh3 = ctx.g.reshape(xi, vec![t_eff, h, p], &nm("heads"));
    let xh = ctx.g.transpose(xh3, vec![1, 0, 2], &nm("heads.T"));
    let dt_h = ctx.g.transpose(dt, vec![1, 0], &nm("dt.T"));

    // chunked SSD with state carry; padded mode walks equal chunks, serve
    // mode ends on a real-length remainder chunk
    let mut state: Option<NodeId> = None;
    let mut ys = Vec::new();
    let mut off = 0usize;
    let mut ci = 0usize;
    while off < t_eff {
        let tc = chunk.min(t_eff - off);
        let cname = format!("l{j}.ssd.c{ci}");
        let nmc = move |s: &str| format!("{cname}.{s}");
        let xh_c = ctx.g.slice(xh, 1, off, tc, &nmc("x"));
        let dt_c = ctx.g.slice(dt_h, 1, off, tc, &nmc("dt"));
        let b_c = ctx.g.slice(b_sel, 0, off, tc, &nmc("b"));
        let c_c = ctx.g.slice(c_sel, 0, off, tc, &nmc("c"));
        let (y_c, s_c) =
            ssd_chunk(ctx, &nmc, tc, h, p, n, xh_c, dt_c, a, b_c, c_c, state);
        ys.push(y_c);
        state = Some(s_c);
        off += tc;
        ci += 1;
    }
    let y = if ys.len() == 1 {
        ys[0]
    } else {
        ctx.g.concat(&ys, 1, &nm("ssd.y"))
    }; // (H, T_eff, P)

    // D skip: y += D[h] * x
    let d_skip = ctx.w(&nm("d_skip"));
    let d_col = ctx.g.reshape(d_skip, vec![h, 1, 1], &nm("D.col"));
    let skip = ctx.g.mul(xh, d_col, &nm("D.skip"));
    let y = ctx.g.add(y, skip, &nm("y.skip"));

    // back to (T, di), drop padding
    let y = ctx.g.transpose(y, vec![1, 0, 2], &nm("y.T")); // (T_eff, H, P)
    let y = ctx.g.reshape(y, vec![t_eff, di], &nm("y.flat"));
    let y = if pad > 0 {
        ctx.g.slice(y, 0, 0, t, &nm("y.unpad"))
    } else {
        y
    };

    // gated RMSNorm, out projection
    let zg = ctx.g.silu(z, &nm("gate.silu"));
    let gated = ctx.g.mul(y, zg, &nm("gate.mul"));
    let gw = ctx.w(&nm("gnorm_w"));
    let yn = ctx.g.rmsnorm(gated, gw, &nm("gnorm"));
    let op = ctx.w(&nm("out_proj"));
    let out = ctx.g.matmul(yn, op, &nm("out_proj.mm"));
    (out, xbc_raw, state.expect("at least one chunk"))
}

/// Batched serving Mamba-2 block over `x` (B, T, d_model): the rank-3
/// mirror of `block_prefill_inner` with `pad_to_chunk = false`, driving
/// [`ssd_chunk_batched`] so the whole bucket runs one (b, t)-shaped node
/// per op. Per-sequence math is the same values in the same order as the
/// single-sequence block — batch is an outer loop in every kernel — so
/// each sequence's outputs stay bitwise identical to
/// [`build_prefill_serve`]. Returns `(out (B, T, d_model), xbc_raw (B,
/// T, conv_dim), state (B, H, P, N))`.
fn block_prefill_batched_inner(
    ctx: &mut Ctx,
    m: &ModelShape,
    j: usize,
    x: NodeId,
    bsz: usize,
    t: usize,
) -> (NodeId, NodeId, NodeId) {
    let (di, n) = (m.d_inner(), m.d_state);
    let (h, p) = (m.n_heads(), m.headdim);
    let chunk = m.chunk;
    let nm_s = move |j: usize, s: &str| format!("l{j}.{s}");
    let nm = |s: &str| nm_s(j, s);

    // single projection emits [z, x, B, C, dt] at once (appendix A.1)
    let in_proj = ctx.w(&nm("in_proj"));
    let zxbcdt = ctx.g.matmul(x, in_proj, &nm("in_proj.mm")); // (B, T, 2di+2n+h)
    let z = ctx.g.slice(zxbcdt, 2, 0, di, &nm("split.z"));
    let xbc_raw = ctx.g.slice(zxbcdt, 2, di, di + 2 * n, &nm("split.xbc"));
    let dt_raw = ctx.g.slice(zxbcdt, 2, 2 * di + 2 * n, h, &nm("split.dt"));

    // conv over (x, B, C) together, then SiLU
    let (cw, cb) = (ctx.w(&nm("conv_w")), ctx.w(&nm("conv_b")));
    let xbc = ctx.g.conv1d_causal(xbc_raw, cw, cb, &nm("conv"));
    let xbc = ctx.g.silu(xbc, &nm("conv.silu"));
    let xi = ctx.g.slice(xbc, 2, 0, di, &nm("split.x"));
    let b_sel = ctx.g.slice(xbc, 2, di, n, &nm("split.B"));
    let c_sel = ctx.g.slice(xbc, 2, di + n, n, &nm("split.C"));

    // dt = softplus(dt_raw + bias) : (B, T, H)
    let dtb = ctx.w(&nm("dt_bias"));
    let dt = ctx.g.add(dt_raw, dtb, &nm("dt.bias"));
    let dt = ctx.g.softplus(dt, &nm("dt.softplus"));

    // a = -exp(a_log) : (H,) -> (H, 1)
    let a_log = ctx.w(&nm("a_log"));
    let a_exp = ctx.g.exp(a_log, &nm("A.exp"));
    let neg1 = ctx.g.const_scalar(&nm("A.neg1"), -1.0);
    let a = ctx.g.mul(a_exp, neg1, &nm("A"));
    let a = ctx.g.reshape(a, vec![h, 1], &nm("A.col"));

    // head layout: (B, T, di) -> (B, H, T, P); dt -> (B, H, T)
    let xh4 = ctx.g.reshape(xi, vec![bsz, t, h, p], &nm("heads"));
    let xh = ctx.g.transpose(xh4, vec![0, 2, 1, 3], &nm("heads.T"));
    let dt_h = ctx.g.transpose(dt, vec![0, 2, 1], &nm("dt.T"));

    // chunked SSD with state carry; serve mode never pads, ending on a
    // real-length remainder chunk so the carried state is decode-exact
    let mut state: Option<NodeId> = None;
    let mut ys = Vec::new();
    let mut off = 0usize;
    let mut ci = 0usize;
    while off < t {
        let tc = chunk.min(t - off);
        let cname = format!("l{j}.ssd.c{ci}");
        let nmc = move |s: &str| format!("{cname}.{s}");
        let xh_c = ctx.g.slice(xh, 2, off, tc, &nmc("x"));
        let dt_c = ctx.g.slice(dt_h, 2, off, tc, &nmc("dt"));
        let b_c = ctx.g.slice(b_sel, 1, off, tc, &nmc("b"));
        let c_c = ctx.g.slice(c_sel, 1, off, tc, &nmc("c"));
        let (y_c, s_c) = ssd_chunk_batched(
            ctx, &nmc, bsz, tc, h, p, n, xh_c, dt_c, a, b_c, c_c, state,
        );
        ys.push(y_c);
        state = Some(s_c);
        off += tc;
        ci += 1;
    }
    let y = if ys.len() == 1 {
        ys[0]
    } else {
        ctx.g.concat(&ys, 2, &nm("ssd.y"))
    }; // (B, H, T, P)

    // D skip: y += D[h] * x
    let d_skip = ctx.w(&nm("d_skip"));
    let d_col = ctx.g.reshape(d_skip, vec![h, 1, 1], &nm("D.col"));
    let skip = ctx.g.mul(xh, d_col, &nm("D.skip"));
    let y = ctx.g.add(y, skip, &nm("y.skip"));

    // back to (B, T, di)
    let y = ctx.g.transpose(y, vec![0, 2, 1, 3], &nm("y.T")); // (B, T, H, P)
    let y = ctx.g.reshape(y, vec![bsz, t, di], &nm("y.flat"));

    // gated RMSNorm, out projection
    let zg = ctx.g.silu(z, &nm("gate.silu"));
    let gated = ctx.g.mul(y, zg, &nm("gate.mul"));
    let gw = ctx.w(&nm("gnorm_w"));
    let yn = ctx.g.rmsnorm(gated, gw, &nm("gnorm"));
    let op = ctx.w(&nm("out_proj"));
    let out = ctx.g.matmul(yn, op, &nm("out_proj.mm"));
    (out, xbc_raw, state.expect("at least one chunk"))
}

/// Full Mamba-2 LM prefill graph: tokens (T,) i32 -> logits (T, V).
pub fn build_prefill(m: &ModelShape, t: usize) -> Graph {
    assert_eq!(m.arch, "mamba2");
    let spec = full_spec(m);
    let mut ctx = Ctx::new(&format!("{}-prefill-t{t}", m.name), &spec);
    let tokens = ctx.g.input_i32("tokens", vec![t]);
    let emb = ctx.w("emb");
    let mut x = ctx.g.gather(emb, tokens, "embed");
    for j in 0..m.n_layers {
        let norm_w = ctx.w(&format!("l{j}.norm_w"));
        let xn = ctx.g.rmsnorm(x, norm_w, &format!("l{j}.norm"));
        let y = block_prefill(&mut ctx, m, j, xn, t);
        x = ctx.g.add(x, y, &format!("l{j}.residual"));
    }
    let fw = ctx.w("final_norm_w");
    let x = ctx.g.rmsnorm(x, fw, "final_norm");
    let emb_t = ctx.g.transpose(emb, vec![1, 0], "lm_head.wT");
    let logits = ctx.g.matmul(x, emb_t, "lm_head.mm");
    ctx.g.output(logits);
    ctx.g
}

/// Single Mamba-2 block graph over (T, d_model) — the Fig-1 / Fig-4(a)(b)
/// profiling workload. At T=4, chunk=256 this contains the paper's exact
/// 256x256 CumSum_b while projections stay at T=4.
pub fn build_block(m: &ModelShape, t: usize) -> Graph {
    assert_eq!(m.arch, "mamba2");
    let spec = block_spec(m);
    let mut ctx = Ctx::new(&format!("{}-block-t{t}", m.name), &spec);
    let x = ctx.g.input("x", vec![t, m.d_model]);
    let (y, state) = block_prefill_with_state(&mut ctx, m, 0, x, t);
    ctx.g.output(y);
    ctx.g.output(state); // prefill caches the SSD state for decode
    ctx.g
}

/// One Mamba-2 block for the *serving* prefill: `block_prefill_inner`
/// with `pad_to_chunk = false`, so the returned SSD state is decode-exact
/// (see the inner builder's doc for why padding would corrupt it), plus
/// the decode conv state — the last K-1 rows of the raw pre-conv `xbc`
/// sequence, the exact window `build_decode_batched` concatenates its
/// next token onto.
fn block_prefill_serve(
    ctx: &mut Ctx,
    m: &ModelShape,
    j: usize,
    x: NodeId,
    t: usize,
) -> (NodeId, NodeId, NodeId) {
    let k = m.d_conv;
    let (out, xbc_raw, state) = block_prefill_inner(ctx, m, j, x, t, false);
    let conv_state =
        ctx.g.slice(xbc_raw, 0, t - (k - 1), k - 1, &format!("l{j}.conv.state"));
    (out, conv_state, state)
}

/// Resume variant of the serving block: `conv_in` (K-1, conv_dim)
/// carries the raw pre-conv (x, B, C) rows of the previous chunk's last
/// K-1 tokens, `ssm_in` (H, P, N) seeds the SSD carry, so the FIRST
/// chunk here runs the same incoming-state path (`off.*` / `carry.*`
/// nodes) the monolithic walk uses for every chunk past its first. At
/// chunk-multiple boundaries the resumed math is bitwise identical to
/// the monolithic prefill; from a decode-produced state it is a
/// decode-exact continuation at any offset. Returns `(block_out,
/// new_conv_state (K-1, conv_dim), ssd state (H, P, N))`.
fn block_prefill_resume(
    ctx: &mut Ctx,
    m: &ModelShape,
    j: usize,
    x: NodeId,
    t: usize,
    conv_in: NodeId,
    ssm_in: NodeId,
) -> (NodeId, NodeId, NodeId) {
    let (di, n) = (m.d_inner(), m.d_state);
    let (h, p) = (m.n_heads(), m.headdim);
    let (k, chunk) = (m.d_conv, m.chunk);
    let nm_s = move |j: usize, s: &str| format!("l{j}.{s}");
    let nm = |s: &str| nm_s(j, s);

    // single projection emits [z, x, B, C, dt] at once (appendix A.1)
    let in_proj = ctx.w(&nm("in_proj"));
    let zxbcdt = ctx.g.matmul(x, in_proj, &nm("in_proj.mm"));
    let z = ctx.g.slice(zxbcdt, 1, 0, di, &nm("split.z"));
    let xbc_raw = ctx.g.slice(zxbcdt, 1, di, di + 2 * n, &nm("split.xbc"));
    let dt_raw = ctx.g.slice(zxbcdt, 1, 2 * di + 2 * n, h, &nm("split.dt"));

    // extend the raw (x, B, C) conv input with the carried tail, conv
    // over (K-1+T, conv_dim), keep only the T new rows — each has a full
    // real window, so the rows match the monolithic conv bitwise
    let ext = ctx.g.concat(&[conv_in, xbc_raw], 0, &nm("conv.ext"));
    let (cw, cb) = (ctx.w(&nm("conv_w")), ctx.w(&nm("conv_b")));
    let xbc_ext = ctx.g.conv1d_causal(ext, cw, cb, &nm("conv"));
    let xbc = ctx.g.slice(xbc_ext, 0, k - 1, t, &nm("conv.new"));
    let xbc = ctx.g.silu(xbc, &nm("conv.silu"));
    // next chunk's carry: the last K-1 raw rows of the extended sequence
    let new_conv = ctx.g.slice(ext, 0, t, k - 1, &nm("conv.state"));

    let xi = ctx.g.slice(xbc, 1, 0, di, &nm("split.x"));
    let b_sel = ctx.g.slice(xbc, 1, di, n, &nm("split.B"));
    let c_sel = ctx.g.slice(xbc, 1, di + n, n, &nm("split.C"));

    // dt = softplus(dt_raw + bias) over the T new rows only
    let dtb = ctx.w(&nm("dt_bias"));
    let dt = ctx.g.add(dt_raw, dtb, &nm("dt.bias"));
    let dt = ctx.g.softplus(dt, &nm("dt.softplus"));

    // a = -exp(a_log) : (H,) -> (H, 1)
    let a_log = ctx.w(&nm("a_log"));
    let a_exp = ctx.g.exp(a_log, &nm("A.exp"));
    let neg1 = ctx.g.const_scalar(&nm("A.neg1"), -1.0);
    let a = ctx.g.mul(a_exp, neg1, &nm("A"));
    let a = ctx.g.reshape(a, vec![h, 1], &nm("A.col"));

    // head layout: (T, di) -> (H, T, P); dt -> (H, T)
    let xh3 = ctx.g.reshape(xi, vec![t, h, p], &nm("heads"));
    let xh = ctx.g.transpose(xh3, vec![1, 0, 2], &nm("heads.T"));
    let dt_h = ctx.g.transpose(dt, vec![1, 0], &nm("dt.T"));

    // chunked SSD, seeded from the carried state: every chunk takes the
    // incoming-state path, exactly like monolithic chunks past the first
    let mut state: Option<NodeId> = Some(ssm_in);
    let mut ys = Vec::new();
    let mut off = 0usize;
    let mut ci = 0usize;
    while off < t {
        let tc = chunk.min(t - off);
        let cname = format!("l{j}.ssd.c{ci}");
        let nmc = move |s: &str| format!("{cname}.{s}");
        let xh_c = ctx.g.slice(xh, 1, off, tc, &nmc("x"));
        let dt_c = ctx.g.slice(dt_h, 1, off, tc, &nmc("dt"));
        let b_c = ctx.g.slice(b_sel, 0, off, tc, &nmc("b"));
        let c_c = ctx.g.slice(c_sel, 0, off, tc, &nmc("c"));
        let (y_c, s_c) =
            ssd_chunk(ctx, &nmc, tc, h, p, n, xh_c, dt_c, a, b_c, c_c, state);
        ys.push(y_c);
        state = Some(s_c);
        off += tc;
        ci += 1;
    }
    let y = if ys.len() == 1 {
        ys[0]
    } else {
        ctx.g.concat(&ys, 1, &nm("ssd.y"))
    }; // (H, T, P)

    // D skip: y += D[h] * x
    let d_skip = ctx.w(&nm("d_skip"));
    let d_col = ctx.g.reshape(d_skip, vec![h, 1, 1], &nm("D.col"));
    let skip = ctx.g.mul(xh, d_col, &nm("D.skip"));
    let y = ctx.g.add(y, skip, &nm("y.skip"));

    // back to (T, di)
    let y = ctx.g.transpose(y, vec![1, 0, 2], &nm("y.T"));
    let y = ctx.g.reshape(y, vec![t, di], &nm("y.flat"));

    // gated RMSNorm, out projection
    let zg = ctx.g.silu(z, &nm("gate.silu"));
    let gated = ctx.g.mul(y, zg, &nm("gate.mul"));
    let gw = ctx.w(&nm("gnorm_w"));
    let yn = ctx.g.rmsnorm(gated, gw, &nm("gnorm"));
    let op = ctx.w(&nm("out_proj"));
    let out = ctx.g.matmul(yn, op, &nm("out_proj.mm"));
    (out, new_conv, state.expect("at least one chunk"))
}

/// Resume serving prefill: tokens (T,) i32 + per-layer `(conv_state,
/// ssm_state)` inputs → last-position logits (1, V) + new states, the
/// same output layout as [`build_prefill_serve`]. Valid for any
/// `t >= 1`; bitwise-identical continuation requires the boundary to
/// land on a multiple of `m.chunk` (`ServeFamily::resume_chunk_grain`).
pub fn build_prefill_serve_resume(m: &ModelShape, t: usize) -> Graph {
    assert_eq!(m.arch, "mamba2");
    let conv_shape = vec![m.d_conv - 1, m.conv_dim()];
    let ssm_shape = vec![m.n_heads(), m.headdim, m.d_state];
    super::serve::lm_serve_scaffold_resume(
        &format!("{}-serve-resume-t{t}", m.name),
        m,
        t,
        &conv_shape,
        &ssm_shape,
        |ctx, j, xn, conv_in, ssm_in| {
            let (y, new_conv, ssd_state) =
                block_prefill_resume(ctx, m, j, xn, t, conv_in, ssm_in);
            (y, (new_conv, ssd_state))
        },
    )
}

/// Serving prefill graph: tokens (T,) i32 -> last-position logits (1, V)
/// plus per-layer decode-ready recurrent state. Output order matches
/// [`build_decode_batched`]: logits, then per layer `conv_state{j}`
/// (K-1, conv_dim) and `ssm_state{j}` (H, P, N).
///
/// Requires `t >= d_conv - 1` so the conv state can be sliced off the
/// prefill window. Any `t` works relative to `chunk` — the SSD runs a
/// real-length remainder chunk instead of padding, so the state outputs
/// are bit-exact continuations for the decode graphs.
pub fn build_prefill_serve(m: &ModelShape, t: usize) -> Graph {
    assert_eq!(m.arch, "mamba2");
    let k = m.d_conv;
    assert!(t >= k - 1, "serve prefill window {t} shorter than conv state {}", k - 1);
    super::serve::lm_serve_scaffold(
        &format!("{}-serve-prefill-t{t}", m.name),
        m,
        t,
        |ctx, j, xn| {
            let (y, conv_state, ssd_state) = block_prefill_serve(ctx, m, j, xn, t);
            (y, (conv_state, ssd_state))
        },
    )
}

/// Batched serving prefill for prefill bucket `b`: tokens (b, T) i32 →
/// logits (b, V) + per-layer batch-stacked decode states. True batch-dim
/// batching: every layer runs ONE (b, t)-shaped node per op via
/// [`block_prefill_batched_inner`] — including the no-padding
/// real-length remainder chunk — instead of replicating the
/// single-sequence graph per sequence, so the planned step count stays
/// flat in `b` while per-sequence results remain bitwise identical to
/// [`build_prefill_serve`] (batch is an outer loop in every kernel).
/// State outputs come out batch-stacked directly: `conv_state{j}` (b,
/// K-1, conv_dim), `ssm_state{j}` (b, H, P, N).
pub fn build_prefill_serve_batched(m: &ModelShape, b: usize, t: usize) -> Graph {
    assert_eq!(m.arch, "mamba2");
    let k = m.d_conv;
    assert!(t >= k - 1, "serve prefill window {t} shorter than conv state {}", k - 1);
    super::serve::lm_serve_scaffold_batched(
        &format!("{}-serve-prefill-b{b}-t{t}", m.name),
        m,
        b,
        t,
        |ctx, j, xn| {
            let (y, xbc_raw, ssd_state) =
                block_prefill_batched_inner(ctx, m, j, xn, b, t);
            let conv_state = ctx.g.slice(
                xbc_raw,
                1,
                t - (k - 1),
                k - 1,
                &format!("l{j}.conv.state"),
            ); // (b, K-1, conv_dim)
            (y, (conv_state, ssd_state))
        },
    )
}

/// Replicated batched serving prefill: same I/O as
/// [`build_prefill_serve_batched`] but each sequence replicates
/// [`build_prefill_serve`] node-for-node. The i8 serving path uses this —
/// its dynamic per-tensor requantize scales would couple co-batched
/// sequences inside one true-batch node (see
/// `serve::lm_serve_scaffold_batched_replicated`).
pub fn build_prefill_serve_batched_replicated(
    m: &ModelShape,
    b: usize,
    t: usize,
) -> Graph {
    assert_eq!(m.arch, "mamba2");
    let k = m.d_conv;
    assert!(t >= k - 1, "serve prefill window {t} shorter than conv state {}", k - 1);
    super::serve::lm_serve_scaffold_batched_replicated(
        &format!("{}-serve-prefill-rep-b{b}-t{t}", m.name),
        m,
        b,
        t,
        |ctx, j, xn| {
            let (y, conv_state, ssd_state) = block_prefill_serve(ctx, m, j, xn, t);
            (y, (conv_state, ssd_state))
        },
    )
}

/// Batched decode-step graph for a fixed batch bucket `b`: tokens (b,)
/// i32 + per-layer stacked states -> logits (b, V) + new states. The
/// Mamba-2 counterpart of `mamba1::build_decode_batched`, and the
/// serving hot path of the planned backend for the SSD family.
///
/// Inputs: params, tokens, then per layer `conv_state{j}` (b, K-1,
/// conv_dim) and `ssm_state{j}` (b, H, P, N). Outputs: logits, then
/// per-layer states in the same order. Every kernel treats the batch
/// dimension independently — elementwise ops broadcast per element,
/// reductions and matmuls loop rows/batches independently — so
/// per-sequence results are bitwise identical across bucket sizes (the
/// pool leans on this to shard a bucket across workers).
pub fn build_decode_batched(m: &ModelShape, b: usize) -> Graph {
    assert_eq!(m.arch, "mamba2");
    assert!(b >= 1, "decode bucket must be >= 1");
    let spec = full_spec(m);
    let mut ctx = Ctx::new(&format!("{}-decode-b{b}", m.name), &spec);
    let tokens = ctx.g.input_i32("tokens", vec![b]);
    let (di, n, k) = (m.d_inner(), m.d_state, m.d_conv);
    let (h, p) = (m.n_heads(), m.headdim);
    let cd = m.conv_dim();
    let mut conv_states = Vec::new();
    let mut ssm_states = Vec::new();
    for j in 0..m.n_layers {
        conv_states.push(ctx.g.input(&format!("conv_state{j}"), vec![b, k - 1, cd]));
        ssm_states.push(ctx.g.input(&format!("ssm_state{j}"), vec![b, h, p, n]));
    }

    let emb = ctx.w("emb");
    let mut x = ctx.g.gather(emb, tokens, "embed"); // (b, d)
    let mut out_states = Vec::new();
    for j in 0..m.n_layers {
        let nm = |s: &str| format!("l{j}.{s}");
        let norm_w = ctx.w(&nm("norm_w"));
        let xn = ctx.g.rmsnorm(x, norm_w, &nm("norm"));
        let in_proj = ctx.w(&nm("in_proj"));
        let zxbcdt = ctx.g.matmul(xn, in_proj, &nm("in_proj.mm")); // (b, 2di+2n+h)
        let z = ctx.g.slice(zxbcdt, 1, 0, di, &nm("split.z"));
        let xbc = ctx.g.slice(zxbcdt, 1, di, di + 2 * n, &nm("split.xbc"));
        let dt_raw = ctx.g.slice(zxbcdt, 1, 2 * di + 2 * n, h, &nm("split.dtr"));

        // conv step: window = [state; x_t] along time, dot with taps
        let xbc_row = ctx.g.reshape(xbc, vec![b, 1, cd], &nm("conv.xrow"));
        let window =
            ctx.g.concat(&[conv_states[j], xbc_row], 1, &nm("conv.win")); // (b, K, cd)
        let cw = ctx.w(&nm("conv_w"));
        let prod = ctx.g.mul(window, cw, &nm("conv.prod"));
        let xbc1 = ctx.g.reduce_sum(prod, 1, &nm("conv.sum")); // (b, cd)
        let cb = ctx.w(&nm("conv_b"));
        let xbc1 = ctx.g.add(xbc1, cb, &nm("conv.bias"));
        let xbc1 = ctx.g.silu(xbc1, &nm("conv.silu"));
        let new_conv = ctx.g.slice(window, 1, 1, k - 1, &nm("conv.state"));

        let xi = ctx.g.slice(xbc1, 1, 0, di, &nm("split.x"));
        let b_t = ctx.g.slice(xbc1, 1, di, n, &nm("split.B")); // (b, n)
        let c_t = ctx.g.slice(xbc1, 1, di + n, n, &nm("split.C"));

        let dtb = ctx.w(&nm("dt_bias"));
        let dt = ctx.g.add(dt_raw, dtb, &nm("dt.bias"));
        let dt = ctx.g.softplus(dt, &nm("dt.softplus")); // (b, h)

        let a_log = ctx.w(&nm("a_log"));
        let a_exp = ctx.g.exp(a_log, &nm("A.exp"));
        let neg1 = ctx.g.const_scalar(&nm("A.neg1"), -1.0);
        let a = ctx.g.mul(a_exp, neg1, &nm("A")); // (h,)

        // state' = state * exp(dt a)[b,h,1,1] + (x dt)[b,h,p,1] * B[b,1,1,n]
        let da = ctx.g.mul(dt, a, &nm("da")); // (b, h)
        let da = ctx.g.exp(da, &nm("decay"));
        let da4 = ctx.g.reshape(da, vec![b, h, 1, 1], &nm("decay.4d"));
        let decayed = ctx.g.mul(ssm_states[j], da4, &nm("h.decay"));
        let xh = ctx.g.reshape(xi, vec![b, h, p], &nm("x.heads"));
        let dt_col = ctx.g.reshape(dt, vec![b, h, 1], &nm("dt.col"));
        let xdt = ctx.g.mul(xh, dt_col, &nm("x.dt")); // (b, h, p)
        let xdt4 = ctx.g.reshape(xdt, vec![b, h, p, 1], &nm("x.dt.4d"));
        let b4 = ctx.g.reshape(b_t, vec![b, 1, 1, n], &nm("B.4d"));
        let inflow = ctx.g.mul(xdt4, b4, &nm("inflow")); // (b, h, p, n)
        let h_new = ctx.g.add(decayed, inflow, &nm("h"));

        // y = state' · C : (b, h, p, n) x (b, h, n, 1) -> (b, h, p, 1)
        let c_mid = ctx.g.reshape(c_t, vec![b, 1, n, 1], &nm("C.mid"));
        let c_col = ctx.g.broadcast(c_mid, vec![b, h, n, 1], &nm("C.col"));
        let y4 = ctx.g.matmul(h_new, c_col, &nm("y.mm"));
        let y = ctx.g.reshape(y4, vec![b, h, p], &nm("y.hp"));
        let d_skip = ctx.w(&nm("d_skip"));
        let d_col = ctx.g.reshape(d_skip, vec![h, 1], &nm("D.col"));
        let skip = ctx.g.mul(xh, d_col, &nm("y.skip"));
        let y = ctx.g.add(y, skip, &nm("y.skipped"));
        let y = ctx.g.reshape(y, vec![b, di], &nm("y.flat"));

        let zg = ctx.g.silu(z, &nm("gate.silu"));
        let gated = ctx.g.mul(y, zg, &nm("gate.mul"));
        let gw = ctx.w(&nm("gnorm_w"));
        let yn = ctx.g.rmsnorm(gated, gw, &nm("gnorm"));
        let op = ctx.w(&nm("out_proj"));
        let y = ctx.g.matmul(yn, op, &nm("out_proj.mm"));
        x = ctx.g.add(x, y, &nm("residual"));
        out_states.push((new_conv, h_new));
    }
    let fw = ctx.w("final_norm_w");
    let x = ctx.g.rmsnorm(x, fw, "final_norm");
    let emb_t = ctx.g.transpose(emb, vec![1, 0], "lm_head.wT");
    let logits = ctx.g.matmul(x, emb_t, "lm_head.mm"); // (b, V)
    ctx.g.output(logits);
    for (cs, ss) in out_states {
        ctx.g.output(cs);
        ctx.g.output(ss);
    }
    ctx.g
}

/// Speculative-verify graph: tokens (b, kw) i32 + per-layer stacked
/// states -> logits at ALL kw positions (b, kw, V) + states advanced by
/// kw steps. The Mamba-2 counterpart of `mamba1::build_verify_batched`.
///
/// Bitwise contract: [`build_decode_batched`] unrolled kw times.
/// Position-independent stages (projections, conv bias/silu, the dt
/// pipeline, gating, norms) batch over a (b, kw, ·) axis — every kernel
/// treats those rows independently — while the conv window extraction
/// and the SSD state recurrence replay decode's exact per-step op
/// sequence, so position p's logits and the final states are bitwise
/// identical to kw sequential decode steps (f32 and f16; i8's dynamic
/// per-tensor scales would couple positions, so it is excluded). Note
/// this is NOT the chunked SSD prefill: that reassociates within a
/// chunk and is only decode-exact at chunk boundaries.
pub fn build_verify_batched(m: &ModelShape, b: usize, kw: usize) -> Graph {
    assert_eq!(m.arch, "mamba2");
    assert!(b >= 1, "verify bucket must be >= 1");
    assert!(kw >= 1, "verify window must be >= 1");
    let spec = full_spec(m);
    let mut ctx = Ctx::new(&format!("{}-verify-b{b}-k{kw}", m.name), &spec);
    let tokens = ctx.g.input_i32("tokens", vec![b, kw]);
    let (di, n, k) = (m.d_inner(), m.d_state, m.d_conv);
    let (h, p_dim) = (m.n_heads(), m.headdim);
    let cd = m.conv_dim();
    let mut conv_states = Vec::new();
    let mut ssm_states = Vec::new();
    for j in 0..m.n_layers {
        conv_states.push(ctx.g.input(&format!("conv_state{j}"), vec![b, k - 1, cd]));
        ssm_states.push(ctx.g.input(&format!("ssm_state{j}"), vec![b, h, p_dim, n]));
    }

    let emb = ctx.w("emb");
    let tok_flat = ctx.g.reshape(tokens, vec![b * kw], "tokens.flat");
    let rows = ctx.g.gather(emb, tok_flat, "embed"); // (b*kw, d)
    let mut x = ctx.g.reshape(rows, vec![b, kw, m.d_model], "embed.batch");
    let mut out_states = Vec::new();
    for j in 0..m.n_layers {
        let nm = |s: &str| format!("l{j}.{s}");
        let norm_w = ctx.w(&nm("norm_w"));
        let xn = ctx.g.rmsnorm(x, norm_w, &nm("norm"));
        let in_proj = ctx.w(&nm("in_proj"));
        let zxbcdt = ctx.g.matmul(xn, in_proj, &nm("in_proj.mm")); // (b, kw, 2di+2n+h)
        let z = ctx.g.slice(zxbcdt, 2, 0, di, &nm("split.z"));
        let xbc = ctx.g.slice(zxbcdt, 2, di, di + 2 * n, &nm("split.xbc"));
        let dt_raw = ctx.g.slice(zxbcdt, 2, 2 * di + 2 * n, h, &nm("split.dtr"));

        // conv: extend the state with the kw raw rows, then each position
        // dots decode's exact (b, K, cd) window against the taps
        let ext = ctx.g.concat(&[conv_states[j], xbc], 1, &nm("conv.ext")); // (b, K-1+kw, cd)
        let cw = ctx.w(&nm("conv_w"));
        let mut xc_rows = Vec::with_capacity(kw);
        for p in 0..kw {
            let pn = |s: &str| format!("l{j}.p{p}.{s}");
            let win = ctx.g.slice(ext, 1, p, k, &pn("conv.win")); // (b, K, cd)
            let prod = ctx.g.mul(win, cw, &pn("conv.prod"));
            let sum = ctx.g.reduce_sum(prod, 1, &pn("conv.sum")); // (b, cd)
            xc_rows.push(ctx.g.reshape(sum, vec![b, 1, cd], &pn("conv.row")));
        }
        let xbc1 = ctx.g.concat(&xc_rows, 1, &nm("conv.taps")); // (b, kw, cd)
        let cb = ctx.w(&nm("conv_b"));
        let xbc1 = ctx.g.add(xbc1, cb, &nm("conv.bias"));
        let xbc1 = ctx.g.silu(xbc1, &nm("conv.silu"));
        let new_conv = ctx.g.slice(ext, 1, kw, k - 1, &nm("conv.state"));

        let xi = ctx.g.slice(xbc1, 2, 0, di, &nm("split.x"));
        let b_t = ctx.g.slice(xbc1, 2, di, n, &nm("split.B")); // (b, kw, n)
        let c_t = ctx.g.slice(xbc1, 2, di + n, n, &nm("split.C"));

        let dtb = ctx.w(&nm("dt_bias"));
        let dt = ctx.g.add(dt_raw, dtb, &nm("dt.bias"));
        let dt = ctx.g.softplus(dt, &nm("dt.softplus")); // (b, kw, h)

        let a_log = ctx.w(&nm("a_log"));
        let a_exp = ctx.g.exp(a_log, &nm("A.exp"));
        let neg1 = ctx.g.const_scalar(&nm("A.neg1"), -1.0);
        let a = ctx.g.mul(a_exp, neg1, &nm("A")); // (h,)

        // position-independent recurrence operands, batched over kw
        let da = ctx.g.mul(dt, a, &nm("da")); // (b, kw, h)
        let da = ctx.g.exp(da, &nm("decay"));
        let xh = ctx.g.reshape(xi, vec![b, kw, h, p_dim], &nm("x.heads"));
        let dt_col = ctx.g.reshape(dt, vec![b, kw, h, 1], &nm("dt.col"));
        let xdt = ctx.g.mul(xh, dt_col, &nm("x.dt")); // (b, kw, h, p)

        // the recurrence itself replays decode's step ops sequentially
        let mut hs = ssm_states[j];
        let mut y_rows = Vec::with_capacity(kw);
        for p in 0..kw {
            let pn = |s: &str| format!("l{j}.p{p}.{s}");
            let da_s = ctx.g.slice(da, 1, p, 1, &pn("decay.s"));
            let da4 = ctx.g.reshape(da_s, vec![b, h, 1, 1], &pn("decay.4d"));
            let decayed = ctx.g.mul(hs, da4, &pn("h.decay"));
            let xdt_s = ctx.g.slice(xdt, 1, p, 1, &pn("x.dt.s"));
            let xdt4 = ctx.g.reshape(xdt_s, vec![b, h, p_dim, 1], &pn("x.dt.4d"));
            let b_s = ctx.g.slice(b_t, 1, p, 1, &pn("B.s"));
            let b4 = ctx.g.reshape(b_s, vec![b, 1, 1, n], &pn("B.4d"));
            let inflow = ctx.g.mul(xdt4, b4, &pn("inflow")); // (b, h, p, n)
            hs = ctx.g.add(decayed, inflow, &pn("h"));
            let c_s = ctx.g.slice(c_t, 1, p, 1, &pn("C.s"));
            let c_mid = ctx.g.reshape(c_s, vec![b, 1, n, 1], &pn("C.mid"));
            let c_col = ctx.g.broadcast(c_mid, vec![b, h, n, 1], &pn("C.col"));
            let y4 = ctx.g.matmul(hs, c_col, &pn("y.mm")); // (b, h, p, 1)
            y_rows.push(ctx.g.reshape(y4, vec![b, 1, h, p_dim], &pn("y.row")));
        }
        let y = ctx.g.concat(&y_rows, 1, &nm("y.cat")); // (b, kw, h, p)
        let d_skip = ctx.w(&nm("d_skip"));
        let d_col = ctx.g.reshape(d_skip, vec![h, 1], &nm("D.col"));
        let skip = ctx.g.mul(xh, d_col, &nm("y.skip"));
        let y = ctx.g.add(y, skip, &nm("y.skipped"));
        let y = ctx.g.reshape(y, vec![b, kw, di], &nm("y.flat"));

        let zg = ctx.g.silu(z, &nm("gate.silu"));
        let gated = ctx.g.mul(y, zg, &nm("gate.mul"));
        let gw = ctx.w(&nm("gnorm_w"));
        let yn = ctx.g.rmsnorm(gated, gw, &nm("gnorm"));
        let op = ctx.w(&nm("out_proj"));
        let y = ctx.g.matmul(yn, op, &nm("out_proj.mm"));
        x = ctx.g.add(x, y, &nm("residual"));
        out_states.push((new_conv, hs));
    }
    let fw = ctx.w("final_norm_w");
    let x = ctx.g.rmsnorm(x, fw, "final_norm");
    let emb_t = ctx.g.transpose(emb, vec![1, 0], "lm_head.wT");
    let logits = ctx.g.matmul(x, emb_t, "lm_head.mm"); // (b, kw, V)
    ctx.g.output(logits);
    for (cs, ss) in out_states {
        ctx.g.output(cs);
        ctx.g.output(ss);
    }
    ctx.g
}

/// Single-token decode-step graph (recurrent SSD update, no chunking).
///
/// Inputs: params, token (1,), per layer `conv_state{j}` (K-1, conv_dim)
/// and `ssm_state{j}` (H, P, N). Outputs: logits + new states.
pub fn build_decode(m: &ModelShape) -> Graph {
    assert_eq!(m.arch, "mamba2");
    let spec = full_spec(m);
    let mut ctx = Ctx::new(&format!("{}-decode", m.name), &spec);
    let token = ctx.g.input_i32("token", vec![1]);
    let (di, n, k) = (m.d_inner(), m.d_state, m.d_conv);
    let (h, p) = (m.n_heads(), m.headdim);
    let cd = m.conv_dim();
    let mut conv_states = Vec::new();
    let mut ssm_states = Vec::new();
    for j in 0..m.n_layers {
        conv_states.push(ctx.g.input(&format!("conv_state{j}"), vec![k - 1, cd]));
        ssm_states.push(ctx.g.input(&format!("ssm_state{j}"), vec![h, p, n]));
    }

    let emb = ctx.w("emb");
    let mut x = ctx.g.gather(emb, token, "embed");
    let mut out_states = Vec::new();
    for j in 0..m.n_layers {
        let nm = |s: &str| format!("l{j}.{s}");
        let norm_w = ctx.w(&nm("norm_w"));
        let xn = ctx.g.rmsnorm(x, norm_w, &nm("norm"));
        let in_proj = ctx.w(&nm("in_proj"));
        let zxbcdt = ctx.g.matmul(xn, in_proj, &nm("in_proj.mm"));
        let z = ctx.g.slice(zxbcdt, 1, 0, di, &nm("split.z"));
        let xbc = ctx.g.slice(zxbcdt, 1, di, di + 2 * n, &nm("split.xbc"));
        let dt_raw = ctx.g.slice(zxbcdt, 1, 2 * di + 2 * n, h, &nm("split.dtr"));

        let window = ctx.g.concat(&[conv_states[j], xbc], 0, &nm("conv.win"));
        let cw = ctx.w(&nm("conv_w"));
        let prod = ctx.g.mul(window, cw, &nm("conv.prod"));
        let xbc1 = ctx.g.reduce_sum(prod, 0, &nm("conv.sum"));
        let cb = ctx.w(&nm("conv_b"));
        let xbc1 = ctx.g.add(xbc1, cb, &nm("conv.bias"));
        let xbc1 = ctx.g.reshape(xbc1, vec![1, cd], &nm("conv.row"));
        let xbc1 = ctx.g.silu(xbc1, &nm("conv.silu"));
        let new_conv = ctx.g.slice(window, 0, 1, k - 1, &nm("conv.state"));

        let xi = ctx.g.slice(xbc1, 1, 0, di, &nm("split.x"));
        let b_t = ctx.g.slice(xbc1, 1, di, n, &nm("split.B")); // (1, n)
        let c_t = ctx.g.slice(xbc1, 1, di + n, n, &nm("split.C"));

        let dtb = ctx.w(&nm("dt_bias"));
        let dt = ctx.g.add(dt_raw, dtb, &nm("dt.bias"));
        let dt = ctx.g.softplus(dt, &nm("dt.softplus")); // (1, h)

        let a_log = ctx.w(&nm("a_log"));
        let a_exp = ctx.g.exp(a_log, &nm("A.exp"));
        let neg1 = ctx.g.const_scalar(&nm("A.neg1"), -1.0);
        let a = ctx.g.mul(a_exp, neg1, &nm("A")); // (h,)

        // state' = state * exp(dt a)[h,1,1] + (x dt)[h,p,1] * B[1,1,n]
        let da = ctx.g.mul(dt, a, &nm("da")); // (1, h)
        let da = ctx.g.exp(da, &nm("decay"));
        let da3 = ctx.g.reshape(da, vec![h, 1, 1], &nm("decay.3d"));
        let decayed = ctx.g.mul(ssm_states[j], da3, &nm("h.decay"));
        let xh = ctx.g.reshape(xi, vec![h, p], &nm("x.heads"));
        let dt_col = ctx.g.reshape(dt, vec![h, 1], &nm("dt.col"));
        let xdt = ctx.g.mul(xh, dt_col, &nm("x.dt")); // (h, p)
        let xdt3 = ctx.g.reshape(xdt, vec![h, p, 1], &nm("x.dt.3d"));
        let b3 = ctx.g.reshape(b_t, vec![1, 1, n], &nm("B.3d"));
        let inflow = ctx.g.mul(xdt3, b3, &nm("inflow")); // (h, p, n)
        let h_new = ctx.g.add(decayed, inflow, &nm("h"));

        // y = state' · C : (h, p, n) x (n, 1) -> (h, p, 1)
        let c_col = ctx.g.reshape(c_t, vec![n, 1], &nm("C.col"));
        let y3 = ctx.g.matmul(h_new, c_col, &nm("y.mm"));
        let y = ctx.g.reshape(y3, vec![h, p], &nm("y.hp"));
        let d_skip = ctx.w(&nm("d_skip"));
        let d_col = ctx.g.reshape(d_skip, vec![h, 1], &nm("D.col"));
        let skip = ctx.g.mul(xh, d_col, &nm("y.skip"));
        let y = ctx.g.add(y, skip, &nm("y.skipped"));
        let y = ctx.g.reshape(y, vec![1, di], &nm("y.flat"));

        let zg = ctx.g.silu(z, &nm("gate.silu"));
        let gated = ctx.g.mul(y, zg, &nm("gate.mul"));
        let gw = ctx.w(&nm("gnorm_w"));
        let yn = ctx.g.rmsnorm(gated, gw, &nm("gnorm"));
        let op = ctx.w(&nm("out_proj"));
        let y = ctx.g.matmul(yn, op, &nm("out_proj.mm"));
        x = ctx.g.add(x, y, &nm("residual"));
        out_states.push((new_conv, h_new));
    }
    let fw = ctx.w("final_norm_w");
    let x = ctx.g.rmsnorm(x, fw, "final_norm");
    let emb_t = ctx.g.transpose(emb, vec![1, 0], "lm_head.wT");
    let logits = ctx.g.matmul(x, emb_t, "lm_head.mm");
    ctx.g.output(logits);
    for (cs, ss) in out_states {
        ctx.g.output(cs);
        ctx.g.output(ss);
    }
    ctx.g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::graph::Census;

    #[test]
    fn block_contains_the_papers_cumsum_b() {
        // T=4, chunk=256: the segsum CumSum must be on (H, 256, 256)
        let m = presets::block130m_mamba2();
        let g = build_block(&m, 4);
        let cs: Vec<_> = g
            .nodes
            .iter()
            .filter(|nd| matches!(nd.op, crate::graph::Op::CumSum { .. }))
            .collect();
        assert!(!cs.is_empty());
        let big = cs
            .iter()
            .find(|nd| nd.name.contains("cumsum_b"))
            .expect("no CumSum_b node");
        assert_eq!(big.shape, vec![24, 256, 256]);
    }

    #[test]
    fn census_shows_mamba2_signature() {
        // new CumSum + ReduceSum ops, fewer MatMul stages (appendix A.1)
        let m = presets::block130m_mamba2();
        let g2 = build_block(&m, 4);
        let c2 = Census::of(&g2);
        assert!(c2.get("CumSum") >= 2);
        assert!(c2.get("ReduceSum") >= 1);
        let m1 = presets::block130m_mamba();
        let c1 = Census::of(&super::super::mamba1::build_block(&m1, 4));
        assert_eq!(c1.get("CumSum"), 0);
        // Mamba-2 single projection vs Mamba-1 staged projections
        assert!(c2.get("MatMul") < c1.get("MatMul"));
        // Mamba-1's unrolled scan gathers/slices far exceed Mamba-2's
        assert!(c1.total > c2.total);
    }

    #[test]
    fn prefill_multi_chunk_carries_state() {
        let m = presets::tiny_mamba2(); // chunk 16
        let g = build_prefill(&m, 64); // 4 chunks
        assert_eq!(g.shape(g.outputs[0]), &[64, m.vocab_size]);
        // chunks beyond the first must reference carried state math
        assert!(g.nodes.iter().any(|nd| nd.name.contains("c1.off.mm")));
    }

    #[test]
    fn decode_graph_state_shapes() {
        let m = presets::tiny_mamba2();
        let g = build_decode(&m);
        assert_eq!(g.outputs.len(), 1 + 2 * m.n_layers);
        assert_eq!(g.shape(g.outputs[1]), &[m.d_conv - 1, m.conv_dim()]);
        assert_eq!(
            g.shape(g.outputs[2]),
            &[m.n_heads(), m.headdim, m.d_state]
        );
    }

    #[test]
    fn serve_prefill_outputs_last_logits_and_states() {
        let m = presets::tiny_mamba2();
        // t = 24 is deliberately NOT a chunk multiple (chunk 16): the
        // serve builder must run a remainder chunk, never pad
        let g = build_prefill_serve(&m, 24);
        assert_eq!(g.outputs.len(), 1 + 2 * m.n_layers);
        assert_eq!(g.shape(g.outputs[0]), &[1, m.vocab_size]);
        assert_eq!(g.shape(g.outputs[1]), &[m.d_conv - 1, m.conv_dim()]);
        assert_eq!(
            g.shape(g.outputs[2]),
            &[m.n_heads(), m.headdim, m.d_state]
        );
        // remainder chunking: a second chunk exists and carries state...
        assert!(g.nodes.iter().any(|nd| nd.name.contains("c1.off.mm")));
        // ...and no pad constants were materialized
        assert!(!g.nodes.iter().any(|nd| nd.name.contains("pad.")));
    }

    #[test]
    fn batched_prefill_keeps_the_no_padding_invariant() {
        let m = presets::tiny_mamba2();
        // t = 24 is not a chunk multiple (chunk 16): every sequence must
        // run a carried remainder chunk, and no pad constants may exist
        let g = build_prefill_serve_batched(&m, 3, 24);
        assert_eq!(g.shape(g.outputs[0]), &[3, m.vocab_size]);
        assert_eq!(g.shape(g.outputs[1]), &[3, m.d_conv - 1, m.conv_dim()]);
        assert_eq!(
            g.shape(g.outputs[2]),
            &[3, m.n_heads(), m.headdim, m.d_state]
        );
        assert!(g.nodes.iter().any(|nd| nd.name.contains("c1.off.mm")));
        assert!(!g.nodes.iter().any(|nd| nd.name.contains("pad.")));
    }

    #[test]
    fn batched_decode_io_shapes() {
        let m = presets::tiny_mamba2();
        let b = 4;
        let g = build_decode_batched(&m, b);
        let n_params = full_spec(&m).entries.len();
        assert_eq!(g.inputs.len(), n_params + 1 + 2 * m.n_layers);
        assert_eq!(g.outputs.len(), 1 + 2 * m.n_layers);
        assert_eq!(g.shape(g.outputs[0]), &[b, m.vocab_size]);
        assert_eq!(g.shape(g.outputs[1]), &[b, m.d_conv - 1, m.conv_dim()]);
        assert_eq!(
            g.shape(g.outputs[2]),
            &[b, m.n_heads(), m.headdim, m.d_state]
        );
    }

    #[test]
    fn resume_continues_monolithic_prefill_bitwise_at_chunk_grain() {
        // split the prompt at chunk multiples (the resume grain): prefill
        // the head from scratch, resume the rest from its state — logits
        // and final states must match the monolithic prefill bit for bit.
        // total = 40 leaves a remainder chunk (chunk 16) on both sides.
        use crate::exec::run_once;
        use crate::graph::Tensor;
        use crate::quality::param_inputs;

        let m = presets::tiny_mamba2();
        let spec = full_spec(&m);
        let mut rng = crate::util::Prng::new(17);
        let weights = rng.range_vec(spec.total(), -0.1, 0.1);
        let params = param_inputs(&spec, &weights);
        let total = 40usize;
        let tokens: Vec<i32> = (0..total as i32).map(|i| 5 + (i * 11) % 60).collect();

        let run = |g: &Graph, extra: Vec<Tensor>| {
            let mut inputs = params.clone();
            inputs.extend(extra);
            run_once(g, &inputs).expect("run")
        };
        let g_full = build_prefill_serve(&m, total);
        let full = run(&g_full, vec![Tensor::i32(vec![total], tokens.clone())]);
        for split in [m.chunk, 2 * m.chunk] {
            let g_head = build_prefill_serve(&m, split);
            let head = run(
                &g_head,
                vec![Tensor::i32(vec![split], tokens[..split].to_vec())],
            );
            let rest = total - split;
            let g_res = build_prefill_serve_resume(&m, rest);
            let mut extra = vec![Tensor::i32(vec![rest], tokens[split..].to_vec())];
            for j in 0..m.n_layers {
                extra.push(head[1 + 2 * j].clone());
                extra.push(head[2 + 2 * j].clone());
            }
            let res = run(&g_res, extra);
            for (i, (a, b)) in full.iter().zip(res.iter()).enumerate() {
                assert_eq!(a.as_f32(), b.as_f32(), "split {split}: output {i} diverges");
            }
        }
    }

    #[test]
    fn batched_decode_is_bitwise_per_sequence() {
        // a b=2 batch must reproduce the two b=1 runs exactly
        use crate::exec::run_once;
        use crate::graph::Tensor;
        use crate::quality::param_inputs;

        let m = presets::tiny_mamba2();
        let spec = full_spec(&m);
        let mut rng = crate::util::Prng::new(13);
        let weights = rng.range_vec(spec.total(), -0.1, 0.1);
        let params = param_inputs(&spec, &weights);
        let (k, cd) = (m.d_conv, m.conv_dim());
        let (h, p, n) = (m.n_heads(), m.headdim, m.d_state);
        let conv_len = (k - 1) * cd;
        let ssm_len = h * p * n;
        let state_f = |seed: u64, len: usize| {
            let mut r = crate::util::Prng::new(seed);
            r.range_vec(len, -0.5, 0.5)
        };
        let conv_seed = |s: usize, j: usize| 3000 + 100 * s as u64 + j as u64;
        let ssm_seed = |s: usize, j: usize| 4000 + 100 * s as u64 + j as u64;

        let g1 = build_decode_batched(&m, 1);
        let g2 = build_decode_batched(&m, 2);
        let mut singles = Vec::new();
        for s in 0..2usize {
            let mut inputs = params.clone();
            inputs.push(Tensor::i32(vec![1], vec![50 + s as i32]));
            for j in 0..m.n_layers {
                inputs.push(Tensor::f32(
                    vec![1, k - 1, cd],
                    state_f(conv_seed(s, j), conv_len),
                ));
                inputs.push(Tensor::f32(
                    vec![1, h, p, n],
                    state_f(ssm_seed(s, j), ssm_len),
                ));
            }
            singles.push(run_once(&g1, &inputs).expect("b=1 decode"));
        }
        let mut inputs = params.clone();
        inputs.push(Tensor::i32(vec![2], vec![50, 51]));
        for j in 0..m.n_layers {
            let mut conv = Vec::new();
            let mut ssm = Vec::new();
            for s in 0..2usize {
                conv.extend(state_f(conv_seed(s, j), conv_len));
                ssm.extend(state_f(ssm_seed(s, j), ssm_len));
            }
            inputs.push(Tensor::f32(vec![2, k - 1, cd], conv));
            inputs.push(Tensor::f32(vec![2, h, p, n], ssm));
        }
        let batched = run_once(&g2, &inputs).expect("b=2 decode");
        let v = m.vocab_size;
        for s in 0..2 {
            assert_eq!(
                &batched[0].as_f32()[s * v..(s + 1) * v],
                singles[s][0].as_f32(),
                "logits diverge for sequence {s}"
            );
            for j in 0..m.n_layers {
                assert_eq!(
                    &batched[1 + 2 * j].as_f32()[s * conv_len..(s + 1) * conv_len],
                    singles[s][1 + 2 * j].as_f32(),
                    "conv state diverges (seq {s}, layer {j})"
                );
                assert_eq!(
                    &batched[2 + 2 * j].as_f32()[s * ssm_len..(s + 1) * ssm_len],
                    singles[s][2 + 2 * j].as_f32(),
                    "ssm state diverges (seq {s}, layer {j})"
                );
            }
        }
    }
}
