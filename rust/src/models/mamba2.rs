//! Mamba-2 model as an IR graph (mirror of `python/compile/mamba2.py`).
//!
//! The SSD layer is built exactly the way a static conversion lowers the
//! official chunked implementation (Listing 1 of Dao & Gu 2024):
//!
//! * the sequence is right-padded to a multiple of `chunk` — this is why
//!   the paper's T=4 Mamba-2 130M graph still contains a 256x256 CumSum:
//!   the segsum runs at chunk resolution regardless of real tokens;
//! * segsum = broadcast -> tril(-1) mask -> **CumSum over a (H, Tc, Tc)
//!   tensor along axis -2** — this node is `CumSum_b` (>99.9 % of
//!   Mamba-2's CumSum time per paper §2.1);
//! * the C·B^T attention-like contraction is lowered as broadcast-Mul +
//!   **ReduceSum** (the einsum decomposition ONNX produces for >2-operand
//!   einsums) — these are the ReduceSum bottlenecks ReduBA targets.

use crate::config::ModelShape;
use crate::graph::{Graph, NodeId};

use super::mamba1::Ctx;
use super::params::{block_spec, full_spec};

/// SSD over one chunk. `xh` (H, Tc, P); `dt_h` (H, Tc); `a` (H, 1);
/// `b`/`c` (Tc, N); `h0` (H, P, N) or None. Returns (y (H, Tc, P), state).
#[allow(clippy::too_many_arguments)]
fn ssd_chunk(
    ctx: &mut Ctx,
    nm: &dyn Fn(&str) -> String,
    tc: usize,
    h: usize,
    _p: usize,
    n: usize,
    xh: NodeId,
    dt_h: NodeId,
    a: NodeId,
    b: NodeId,
    c: NodeId,
    h0: Option<NodeId>,
) -> (NodeId, NodeId) {

    // da = dt * a : (H, Tc)
    let da = ctx.g.mul(dt_h, a, &nm("da"));

    // --- segsum: broadcast -> strict-tril mask -> CumSum_b --------------
    let da_col = ctx.g.reshape(da, vec![h, tc, 1], &nm("segsum.col"));
    let da_rep = ctx.g.broadcast(da_col, vec![h, tc, tc], &nm("segsum.rep"));
    let tril_m1 = ctx.g.const_tril_offset(&nm("segsum.mask"), tc, -1);
    let masked = ctx.g.mul(da_rep, tril_m1, &nm("segsum.masked"));
    // CumSum_b: (H, Tc, Tc) along axis -2 — the paper's 256x256 bottleneck
    let seg = ctx.g.cumsum(masked, 1, &nm("segsum.cumsum_b"));
    let seg_exp = ctx.g.exp(seg, &nm("L.exp"));
    let tril0 = ctx.g.const_tril(&nm("L.mask"), tc);
    let l_mat = ctx.g.mul(seg_exp, tril0, &nm("L")); // (H, Tc, Tc)

    // --- C B^T via broadcast-Mul + ReduceSum (einsum decomposition) -----
    let c_row = ctx.g.reshape(c, vec![tc, 1, n], &nm("cb.c"));
    let b_row = ctx.g.reshape(b, vec![1, tc, n], &nm("cb.b"));
    let cb_big = ctx.g.mul(c_row, b_row, &nm("cb.mul")); // (Tc, Tc, N)
    let cb = ctx.g.reduce_sum(cb_big, 2, &nm("cb.reducesum")); // (Tc, Tc)

    // scores = (C B^T) ⊙ L, then intra-chunk outputs
    let scores = ctx.g.mul(l_mat, cb, &nm("scores")); // (H, Tc, Tc)
    let dt_col = ctx.g.reshape(dt_h, vec![h, tc, 1], &nm("xdt.dt"));
    let xdt = ctx.g.mul(xh, dt_col, &nm("xdt")); // (H, Tc, P)
    let mut y = ctx.g.matmul(scores, xdt, &nm("y.diag")); // (H, Tc, P)

    // --- chunk state: decay-weighted contraction over Tc ----------------
    // da_cs (H, Tc) = cumsum(da); decay = exp(da_cs[last] - da_cs)
    let da_cs = ctx.g.cumsum(da, 1, &nm("state.cumsum"));
    let last = ctx.g.slice(da_cs, 1, tc - 1, 1, &nm("state.last")); // (H,1)
    let diff = ctx.g.sub(last, da_cs, &nm("state.diff"));
    let decay = ctx.g.exp(diff, &nm("state.decay")); // (H, Tc)
    let wgt = ctx.g.mul(decay, dt_h, &nm("state.w")); // (H, Tc)
    let w_col = ctx.g.reshape(wgt, vec![h, tc, 1], &nm("state.w.col"));
    let xw = ctx.g.mul(xh, w_col, &nm("state.xw")); // (H, Tc, P)
    let xw_t = ctx.g.transpose(xw, vec![0, 2, 1], &nm("state.xw.T")); // (H,P,Tc)
    let mut state = ctx.g.matmul(xw_t, b, &nm("state.mm")); // (H, P, N)

    // --- incoming-state contribution (steps 3/4) -------------------------
    if let Some(h0) = h0 {
        let sdo = ctx.g.exp(da_cs, &nm("off.decay")); // (H, Tc)
        let h0_t = ctx.g.transpose(h0, vec![0, 2, 1], &nm("off.h0T")); // (H,N,P)
        let y_off = ctx.g.matmul(c, h0_t, &nm("off.mm")); // (H, Tc, P)
        let sdo_col = ctx.g.reshape(sdo, vec![h, tc, 1], &nm("off.col"));
        let y_off = ctx.g.mul(y_off, sdo_col, &nm("off.scaled"));
        y = ctx.g.add(y, y_off, &nm("y.with_off"));
        let chunk_decay = ctx.g.reshape(last, vec![h, 1, 1], &nm("carry.decay"));
        let chunk_decay = ctx.g.exp(chunk_decay, &nm("carry.exp"));
        let carried = ctx.g.mul(h0, chunk_decay, &nm("carry"));
        state = ctx.g.add(state, carried, &nm("state.total"));
    }
    (y, state)
}

/// One Mamba-2 block over `x` (T, d_model). `t_pad` is T padded up to a
/// chunk multiple (the conversion-time padding of the official code).
pub(crate) fn block_prefill(
    ctx: &mut Ctx,
    m: &ModelShape,
    j: usize,
    x: NodeId,
    t: usize,
) -> NodeId {
    block_prefill_with_state(ctx, m, j, x, t).0
}

/// Like `block_prefill` but also returns the final SSD state node —
/// a real output of the conversion-time prefill graph (it seeds decode),
/// so the profiling/census workloads keep the state math live.
pub(crate) fn block_prefill_with_state(
    ctx: &mut Ctx,
    m: &ModelShape,
    j: usize,
    x: NodeId,
    t: usize,
) -> (NodeId, NodeId) {
    let (di, n) = (m.d_inner(), m.d_state);
    let (h, p) = (m.n_heads(), m.headdim);
    let chunk = m.chunk;
    let t_pad = t.div_ceil(chunk) * chunk;
    let nm_s = move |j: usize, s: &str| format!("l{j}.{s}");
    let nm = |s: &str| nm_s(j, s);

    // single projection emits [z, x, B, C, dt] at once (appendix A.1)
    let in_proj = ctx.w(&nm("in_proj"));
    let zxbcdt = ctx.g.matmul(x, in_proj, &nm("in_proj.mm"));
    let z = ctx.g.slice(zxbcdt, 1, 0, di, &nm("split.z"));
    let xbc = ctx.g.slice(zxbcdt, 1, di, di + 2 * n, &nm("split.xbc"));
    let dt_raw = ctx.g.slice(zxbcdt, 1, 2 * di + 2 * n, h, &nm("split.dt"));

    // conv over (x, B, C) together, then SiLU
    let (cw, cb) = (ctx.w(&nm("conv_w")), ctx.w(&nm("conv_b")));
    let xbc = ctx.g.conv1d_causal(xbc, cw, cb, &nm("conv"));
    let xbc = ctx.g.silu(xbc, &nm("conv.silu"));
    let xi = ctx.g.slice(xbc, 1, 0, di, &nm("split.x"));
    let b_sel = ctx.g.slice(xbc, 1, di, n, &nm("split.B"));
    let c_sel = ctx.g.slice(xbc, 1, di + n, n, &nm("split.C"));

    // dt = softplus(dt_raw + bias) : (T, H)
    let dtb = ctx.w(&nm("dt_bias"));
    let dt = ctx.g.add(dt_raw, dtb, &nm("dt.bias"));
    let dt = ctx.g.softplus(dt, &nm("dt.softplus"));

    // a = -exp(a_log) : (H,) -> (H, 1)
    let a_log = ctx.w(&nm("a_log"));
    let a_exp = ctx.g.exp(a_log, &nm("A.exp"));
    let neg1 = ctx.g.const_scalar(&nm("A.neg1"), -1.0);
    let a = ctx.g.mul(a_exp, neg1, &nm("A"));
    let a = ctx.g.reshape(a, vec![h, 1], &nm("A.col"));

    // pad sequence dim to chunk multiple (zeros: dt rows are garbage on
    // pads but dt only multiplies x = 0 there, and y pads are sliced off)
    let pad = t_pad - t;
    let (xi, b_sel, c_sel, dt) = if pad > 0 {
        let zx = crate::graph::Tensor::zeros(vec![pad, di]);
        let zn = crate::graph::Tensor::zeros(vec![pad, n]);
        let zh = crate::graph::Tensor::zeros(vec![pad, h]);
        let px = ctx.g.constant(&nm("pad.x"), zx);
        let pb = ctx.g.constant(&nm("pad.b"), zn.clone());
        let pc = ctx.g.constant(&nm("pad.c"), zn);
        let pd = ctx.g.constant(&nm("pad.dt"), zh);
        (
            ctx.g.concat(&[xi, px], 0, &nm("pad.cat.x")),
            ctx.g.concat(&[b_sel, pb], 0, &nm("pad.cat.b")),
            ctx.g.concat(&[c_sel, pc], 0, &nm("pad.cat.c")),
            ctx.g.concat(&[dt, pd], 0, &nm("pad.cat.dt")),
        )
    } else {
        (xi, b_sel, c_sel, dt)
    };

    // head layout: (T, di) -> (H, T, P); dt -> (H, T)
    let xh3 = ctx.g.reshape(xi, vec![t_pad, h, p], &nm("heads"));
    let xh = ctx.g.transpose(xh3, vec![1, 0, 2], &nm("heads.T"));
    let dt_h = ctx.g.transpose(dt, vec![1, 0], &nm("dt.T"));

    // chunked SSD with state carry
    let n_chunks = t_pad / chunk;
    let mut state: Option<NodeId> = None;
    let mut ys = Vec::with_capacity(n_chunks);
    for ci in 0..n_chunks {
        let cname = format!("l{j}.ssd.c{ci}");
        let nmc = move |s: &str| format!("{cname}.{s}");
        let xh_c = ctx.g.slice(xh, 1, ci * chunk, chunk, &nmc("x"));
        let dt_c = ctx.g.slice(dt_h, 1, ci * chunk, chunk, &nmc("dt"));
        let b_c = ctx.g.slice(b_sel, 0, ci * chunk, chunk, &nmc("b"));
        let c_c = ctx.g.slice(c_sel, 0, ci * chunk, chunk, &nmc("c"));
        let (y_c, s_c) =
            ssd_chunk(ctx, &nmc, chunk, h, p, n, xh_c, dt_c, a, b_c, c_c, state);
        ys.push(y_c);
        state = Some(s_c);
    }
    let y = if ys.len() == 1 {
        ys[0]
    } else {
        ctx.g.concat(&ys, 1, &nm("ssd.y"))
    }; // (H, T_pad, P)

    // D skip: y += D[h] * x
    let d_skip = ctx.w(&nm("d_skip"));
    let d_col = ctx.g.reshape(d_skip, vec![h, 1, 1], &nm("D.col"));
    let skip = ctx.g.mul(xh, d_col, &nm("D.skip"));
    let y = ctx.g.add(y, skip, &nm("y.skip"));

    // back to (T, di), drop padding
    let y = ctx.g.transpose(y, vec![1, 0, 2], &nm("y.T")); // (T_pad, H, P)
    let y = ctx.g.reshape(y, vec![t_pad, di], &nm("y.flat"));
    let y = if pad > 0 {
        ctx.g.slice(y, 0, 0, t, &nm("y.unpad"))
    } else {
        y
    };

    // gated RMSNorm, out projection
    let zg = ctx.g.silu(z, &nm("gate.silu"));
    let gated = ctx.g.mul(y, zg, &nm("gate.mul"));
    let gw = ctx.w(&nm("gnorm_w"));
    let yn = ctx.g.rmsnorm(gated, gw, &nm("gnorm"));
    let op = ctx.w(&nm("out_proj"));
    let out = ctx.g.matmul(yn, op, &nm("out_proj.mm"));
    (out, state.expect("at least one chunk"))
}

/// Full Mamba-2 LM prefill graph: tokens (T,) i32 -> logits (T, V).
pub fn build_prefill(m: &ModelShape, t: usize) -> Graph {
    assert_eq!(m.arch, "mamba2");
    let spec = full_spec(m);
    let mut ctx = Ctx::new(&format!("{}-prefill-t{t}", m.name), &spec);
    let tokens = ctx.g.input_i32("tokens", vec![t]);
    let emb = ctx.w("emb");
    let mut x = ctx.g.gather(emb, tokens, "embed");
    for j in 0..m.n_layers {
        let norm_w = ctx.w(&format!("l{j}.norm_w"));
        let xn = ctx.g.rmsnorm(x, norm_w, &format!("l{j}.norm"));
        let y = block_prefill(&mut ctx, m, j, xn, t);
        x = ctx.g.add(x, y, &format!("l{j}.residual"));
    }
    let fw = ctx.w("final_norm_w");
    let x = ctx.g.rmsnorm(x, fw, "final_norm");
    let emb_t = ctx.g.transpose(emb, vec![1, 0], "lm_head.wT");
    let logits = ctx.g.matmul(x, emb_t, "lm_head.mm");
    ctx.g.output(logits);
    ctx.g
}

/// Single Mamba-2 block graph over (T, d_model) — the Fig-1 / Fig-4(a)(b)
/// profiling workload. At T=4, chunk=256 this contains the paper's exact
/// 256x256 CumSum_b while projections stay at T=4.
pub fn build_block(m: &ModelShape, t: usize) -> Graph {
    assert_eq!(m.arch, "mamba2");
    let spec = block_spec(m);
    let mut ctx = Ctx::new(&format!("{}-block-t{t}", m.name), &spec);
    let x = ctx.g.input("x", vec![t, m.d_model]);
    let (y, state) = block_prefill_with_state(&mut ctx, m, 0, x, t);
    ctx.g.output(y);
    ctx.g.output(state); // prefill caches the SSD state for decode
    ctx.g
}

/// Single-token decode-step graph (recurrent SSD update, no chunking).
///
/// Inputs: params, token (1,), per layer `conv_state{j}` (K-1, conv_dim)
/// and `ssm_state{j}` (H, P, N). Outputs: logits + new states.
pub fn build_decode(m: &ModelShape) -> Graph {
    assert_eq!(m.arch, "mamba2");
    let spec = full_spec(m);
    let mut ctx = Ctx::new(&format!("{}-decode", m.name), &spec);
    let token = ctx.g.input_i32("token", vec![1]);
    let (di, n, k) = (m.d_inner(), m.d_state, m.d_conv);
    let (h, p) = (m.n_heads(), m.headdim);
    let cd = m.conv_dim();
    let mut conv_states = Vec::new();
    let mut ssm_states = Vec::new();
    for j in 0..m.n_layers {
        conv_states.push(ctx.g.input(&format!("conv_state{j}"), vec![k - 1, cd]));
        ssm_states.push(ctx.g.input(&format!("ssm_state{j}"), vec![h, p, n]));
    }

    let emb = ctx.w("emb");
    let mut x = ctx.g.gather(emb, token, "embed");
    let mut out_states = Vec::new();
    for j in 0..m.n_layers {
        let nm = |s: &str| format!("l{j}.{s}");
        let norm_w = ctx.w(&nm("norm_w"));
        let xn = ctx.g.rmsnorm(x, norm_w, &nm("norm"));
        let in_proj = ctx.w(&nm("in_proj"));
        let zxbcdt = ctx.g.matmul(xn, in_proj, &nm("in_proj.mm"));
        let z = ctx.g.slice(zxbcdt, 1, 0, di, &nm("split.z"));
        let xbc = ctx.g.slice(zxbcdt, 1, di, di + 2 * n, &nm("split.xbc"));
        let dt_raw = ctx.g.slice(zxbcdt, 1, 2 * di + 2 * n, h, &nm("split.dtr"));

        let window = ctx.g.concat(&[conv_states[j], xbc], 0, &nm("conv.win"));
        let cw = ctx.w(&nm("conv_w"));
        let prod = ctx.g.mul(window, cw, &nm("conv.prod"));
        let xbc1 = ctx.g.reduce_sum(prod, 0, &nm("conv.sum"));
        let cb = ctx.w(&nm("conv_b"));
        let xbc1 = ctx.g.add(xbc1, cb, &nm("conv.bias"));
        let xbc1 = ctx.g.reshape(xbc1, vec![1, cd], &nm("conv.row"));
        let xbc1 = ctx.g.silu(xbc1, &nm("conv.silu"));
        let new_conv = ctx.g.slice(window, 0, 1, k - 1, &nm("conv.state"));

        let xi = ctx.g.slice(xbc1, 1, 0, di, &nm("split.x"));
        let b_t = ctx.g.slice(xbc1, 1, di, n, &nm("split.B")); // (1, n)
        let c_t = ctx.g.slice(xbc1, 1, di + n, n, &nm("split.C"));

        let dtb = ctx.w(&nm("dt_bias"));
        let dt = ctx.g.add(dt_raw, dtb, &nm("dt.bias"));
        let dt = ctx.g.softplus(dt, &nm("dt.softplus")); // (1, h)

        let a_log = ctx.w(&nm("a_log"));
        let a_exp = ctx.g.exp(a_log, &nm("A.exp"));
        let neg1 = ctx.g.const_scalar(&nm("A.neg1"), -1.0);
        let a = ctx.g.mul(a_exp, neg1, &nm("A")); // (h,)

        // state' = state * exp(dt a)[h,1,1] + (x dt)[h,p,1] * B[1,1,n]
        let da = ctx.g.mul(dt, a, &nm("da")); // (1, h)
        let da = ctx.g.exp(da, &nm("decay"));
        let da3 = ctx.g.reshape(da, vec![h, 1, 1], &nm("decay.3d"));
        let decayed = ctx.g.mul(ssm_states[j], da3, &nm("h.decay"));
        let xh = ctx.g.reshape(xi, vec![h, p], &nm("x.heads"));
        let dt_col = ctx.g.reshape(dt, vec![h, 1], &nm("dt.col"));
        let xdt = ctx.g.mul(xh, dt_col, &nm("x.dt")); // (h, p)
        let xdt3 = ctx.g.reshape(xdt, vec![h, p, 1], &nm("x.dt.3d"));
        let b3 = ctx.g.reshape(b_t, vec![1, 1, n], &nm("B.3d"));
        let inflow = ctx.g.mul(xdt3, b3, &nm("inflow")); // (h, p, n)
        let h_new = ctx.g.add(decayed, inflow, &nm("h"));

        // y = state' · C : (h, p, n) x (n, 1) -> (h, p, 1)
        let c_col = ctx.g.reshape(c_t, vec![n, 1], &nm("C.col"));
        let y3 = ctx.g.matmul(h_new, c_col, &nm("y.mm"));
        let y = ctx.g.reshape(y3, vec![h, p], &nm("y.hp"));
        let d_skip = ctx.w(&nm("d_skip"));
        let d_col = ctx.g.reshape(d_skip, vec![h, 1], &nm("D.col"));
        let skip = ctx.g.mul(xh, d_col, &nm("y.skip"));
        let y = ctx.g.add(y, skip, &nm("y.skipped"));
        let y = ctx.g.reshape(y, vec![1, di], &nm("y.flat"));

        let zg = ctx.g.silu(z, &nm("gate.silu"));
        let gated = ctx.g.mul(y, zg, &nm("gate.mul"));
        let gw = ctx.w(&nm("gnorm_w"));
        let yn = ctx.g.rmsnorm(gated, gw, &nm("gnorm"));
        let op = ctx.w(&nm("out_proj"));
        let y = ctx.g.matmul(yn, op, &nm("out_proj.mm"));
        x = ctx.g.add(x, y, &nm("residual"));
        out_states.push((new_conv, h_new));
    }
    let fw = ctx.w("final_norm_w");
    let x = ctx.g.rmsnorm(x, fw, "final_norm");
    let emb_t = ctx.g.transpose(emb, vec![1, 0], "lm_head.wT");
    let logits = ctx.g.matmul(x, emb_t, "lm_head.mm");
    ctx.g.output(logits);
    for (cs, ss) in out_states {
        ctx.g.output(cs);
        ctx.g.output(ss);
    }
    ctx.g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::graph::Census;

    #[test]
    fn block_contains_the_papers_cumsum_b() {
        // T=4, chunk=256: the segsum CumSum must be on (H, 256, 256)
        let m = presets::block130m_mamba2();
        let g = build_block(&m, 4);
        let cs: Vec<_> = g
            .nodes
            .iter()
            .filter(|nd| matches!(nd.op, crate::graph::Op::CumSum { .. }))
            .collect();
        assert!(!cs.is_empty());
        let big = cs
            .iter()
            .find(|nd| nd.name.contains("cumsum_b"))
            .expect("no CumSum_b node");
        assert_eq!(big.shape, vec![24, 256, 256]);
    }

    #[test]
    fn census_shows_mamba2_signature() {
        // new CumSum + ReduceSum ops, fewer MatMul stages (appendix A.1)
        let m = presets::block130m_mamba2();
        let g2 = build_block(&m, 4);
        let c2 = Census::of(&g2);
        assert!(c2.get("CumSum") >= 2);
        assert!(c2.get("ReduceSum") >= 1);
        let m1 = presets::block130m_mamba();
        let c1 = Census::of(&super::super::mamba1::build_block(&m1, 4));
        assert_eq!(c1.get("CumSum"), 0);
        // Mamba-2 single projection vs Mamba-1 staged projections
        assert!(c2.get("MatMul") < c1.get("MatMul"));
        // Mamba-1's unrolled scan gathers/slices far exceed Mamba-2's
        assert!(c1.total > c2.total);
    }

    #[test]
    fn prefill_multi_chunk_carries_state() {
        let m = presets::tiny_mamba2(); // chunk 16
        let g = build_prefill(&m, 64); // 4 chunks
        assert_eq!(g.shape(g.outputs[0]), &[64, m.vocab_size]);
        // chunks beyond the first must reference carried state math
        assert!(g.nodes.iter().any(|nd| nd.name.contains("c1.off.mm")));
    }

    #[test]
    fn decode_graph_state_shapes() {
        let m = presets::tiny_mamba2();
        let g = build_decode(&m);
        assert_eq!(g.outputs.len(), 1 + 2 * m.n_layers);
        assert_eq!(g.shape(g.outputs[1]), &[m.d_conv - 1, m.conv_dim()]);
        assert_eq!(
            g.shape(g.outputs[2]),
            &[m.n_heads(), m.headdim, m.d_state]
        );
    }
}
