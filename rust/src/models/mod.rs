//! Mamba / Mamba-2 models expressed in the IR (the simulator-side mirror
//! of the JAX L2 models; weights load from the same AOT `.bin` files).

pub mod mamba1;
pub mod mamba2;
pub mod params;
pub mod serve;

use crate::config::ModelShape;
use crate::graph::Graph;

/// Build the full-LM prefill graph for either architecture.
pub fn build_prefill(m: &ModelShape, t: usize) -> Graph {
    match m.arch.as_str() {
        "mamba" => mamba1::build_prefill(m, t),
        "mamba2" => mamba2::build_prefill(m, t),
        other => panic!("unknown arch {other}"),
    }
}

/// Build the single-block profiling graph for either architecture.
pub fn build_block(m: &ModelShape, t: usize) -> Graph {
    match m.arch.as_str() {
        "mamba" => mamba1::build_block(m, t),
        "mamba2" => mamba2::build_block(m, t),
        other => panic!("unknown arch {other}"),
    }
}

/// Build the single-token decode graph for either architecture.
pub fn build_decode(m: &ModelShape) -> Graph {
    match m.arch.as_str() {
        "mamba" => mamba1::build_decode(m),
        "mamba2" => mamba2::build_decode(m),
        other => panic!("unknown arch {other}"),
    }
}

pub use serve::ServeFamily;

/// Build the serving prefill graph (last-position logits + per-layer
/// decode state) for either architecture.
pub fn build_prefill_serve(m: &ModelShape, t: usize) -> Graph {
    ServeFamily::from_arch(&m.arch)
        .unwrap_or_else(|e| panic!("{e}"))
        .build_prefill_serve(m, t)
}

/// Build the bucket-`b` batched decode-step graph for either architecture.
pub fn build_decode_batched(m: &ModelShape, b: usize) -> Graph {
    ServeFamily::from_arch(&m.arch)
        .unwrap_or_else(|e| panic!("{e}"))
        .build_decode_batched(m, b)
}

/// Build the bucket-`b` batched serving-prefill graph (per-sequence
/// bitwise identical to `build_prefill_serve`) for either architecture.
pub fn build_prefill_batched(m: &ModelShape, b: usize, t: usize) -> Graph {
    ServeFamily::from_arch(&m.arch)
        .unwrap_or_else(|e| panic!("{e}"))
        .build_prefill_batched(m, b, t)
}

/// Build the resume serving-prefill graph (per-layer state enters as
/// inputs; continues a cached snapshot bitwise at the family's resume
/// grain) for either architecture.
pub fn build_prefill_resume(m: &ModelShape, t: usize) -> Graph {
    ServeFamily::from_arch(&m.arch)
        .unwrap_or_else(|e| panic!("{e}"))
        .build_prefill_resume(m, t)
}
