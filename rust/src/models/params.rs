//! Flat-buffer parameter layout — exact mirror of
//! `python/compile/layers.ParamSpec` + the per-arch `add_block_params`.
//!
//! The AOT weights `.bin` files are raw little-endian f32 in this order;
//! keeping the layout duplicated (and tested against the manifest's
//! `weights_len`) lets the rust interpreter and simulator consume the same
//! trained weights the PJRT artifacts use, with no pickle in sight.

use crate::config::ModelShape;
use crate::graph::Tensor;

/// One named parameter: shape + offset into the flat buffer.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
}

/// Ordered parameter layout.
#[derive(Clone, Debug, Default)]
pub struct ParamSpec {
    pub entries: Vec<ParamEntry>,
    total: usize,
}

impl ParamSpec {
    pub fn add(&mut self, name: &str, shape: &[usize]) {
        let size: usize = shape.iter().product();
        self.entries.push(ParamEntry {
            name: name.to_string(),
            shape: shape.to_vec(),
            offset: self.total,
        });
        self.total += size;
    }

    pub fn total(&self) -> usize {
        self.total
    }

    pub fn find(&self, name: &str) -> Option<&ParamEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Slice one named parameter out of the flat buffer.
    pub fn extract(&self, buf: &[f32], name: &str) -> Option<Tensor> {
        let e = self.find(name)?;
        let size: usize = e.shape.iter().product();
        if e.offset + size > buf.len() {
            return None;
        }
        Some(Tensor::f32(
            e.shape.clone(),
            buf[e.offset..e.offset + size].to_vec(),
        ))
    }
}

/// Mamba-1 per-block parameters (order matches python `mamba.add_block_params`).
fn add_mamba1_block(spec: &mut ParamSpec, m: &ModelShape, j: usize) {
    let (d, di, n) = (m.d_model, m.d_inner(), m.d_state);
    let (r, k) = (m.resolved_dt_rank(), m.d_conv);
    let p = |s: &str| format!("l{j}.{s}");
    spec.add(&p("norm_w"), &[d]);
    spec.add(&p("in_proj"), &[d, 2 * di]);
    spec.add(&p("conv_w"), &[k, di]);
    spec.add(&p("conv_b"), &[di]);
    spec.add(&p("x_proj"), &[di, r + 2 * n]);
    spec.add(&p("dt_proj_w"), &[r, di]);
    spec.add(&p("dt_proj_b"), &[di]);
    spec.add(&p("a_log"), &[di, n]);
    spec.add(&p("d_skip"), &[di]);
    spec.add(&p("out_proj"), &[di, d]);
}

/// Mamba-2 per-block parameters (order matches python `mamba2.add_block_params`).
fn add_mamba2_block(spec: &mut ParamSpec, m: &ModelShape, j: usize) {
    let (d, di, n) = (m.d_model, m.d_inner(), m.d_state);
    let (h, k, cd) = (m.n_heads(), m.d_conv, m.conv_dim());
    let p = |s: &str| format!("l{j}.{s}");
    spec.add(&p("norm_w"), &[d]);
    spec.add(&p("in_proj"), &[d, 2 * di + 2 * n + h]);
    spec.add(&p("conv_w"), &[k, cd]);
    spec.add(&p("conv_b"), &[cd]);
    spec.add(&p("dt_bias"), &[h]);
    spec.add(&p("a_log"), &[h]);
    spec.add(&p("d_skip"), &[h]);
    spec.add(&p("gnorm_w"), &[di]);
    spec.add(&p("out_proj"), &[di, d]);
}

/// Full-model parameter layout (mirror of python `model.build_spec`).
pub fn full_spec(m: &ModelShape) -> ParamSpec {
    let mut spec = ParamSpec::default();
    spec.add("emb", &[m.vocab_size, m.d_model]);
    for j in 0..m.n_layers {
        if m.arch == "mamba" {
            add_mamba1_block(&mut spec, m, j);
        } else {
            add_mamba2_block(&mut spec, m, j);
        }
    }
    spec.add("final_norm_w", &[m.d_model]);
    spec
}

/// Single-block layout (mirror of python `aot.block_spec`).
pub fn block_spec(m: &ModelShape) -> ParamSpec {
    let mut spec = ParamSpec::default();
    if m.arch == "mamba" {
        add_mamba1_block(&mut spec, m, 0);
    } else {
        add_mamba2_block(&mut spec, m, 0);
    }
    spec
}

/// `extract` that panics with the parameter name on failure (tests).
pub fn extract_or_panic(spec: &ParamSpec, buf: &[f32], name: &str) -> Tensor {
    spec.extract(buf, name)
        .unwrap_or_else(|| panic!("cannot extract param {name}"))
}

/// Load a raw little-endian f32 weights file.
pub fn load_f32_bin(path: &str) -> Result<Vec<f32>, String> {
    let bytes = std::fs::read(path).map_err(|e| format!("read {path}: {e}"))?;
    if bytes.len() % 4 != 0 {
        return Err(format!("{path}: size {} not a multiple of 4", bytes.len()));
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn tiny_mamba_total_matches_python() {
        // python printed: tiny-mamba params: 266112
        assert_eq!(full_spec(&presets::tiny_mamba()).total(), 266_112);
    }

    #[test]
    fn tiny_mamba2_total_matches_python() {
        // python printed: tiny-mamba2 params: 251952
        assert_eq!(full_spec(&presets::tiny_mamba2()).total(), 251_952);
    }

    #[test]
    fn block_specs_match_python_block_weights() {
        // aot.py printed 3771648 / 3765320 f32 for the block .bin files
        assert_eq!(block_spec(&presets::block130m_mamba()).total(), 3_771_648);
        assert_eq!(block_spec(&presets::block130m_mamba2()).total(), 3_765_320);
    }

    #[test]
    fn extract_respects_offsets() {
        let m = presets::tiny_mamba();
        let spec = full_spec(&m);
        let buf: Vec<f32> = (0..spec.total()).map(|i| i as f32).collect();
        let e = spec.find("l0.conv_b").unwrap().clone();
        let t = spec.extract(&buf, "l0.conv_b").unwrap();
        assert_eq!(t.shape, e.shape);
        assert_eq!(t.as_f32()[0], e.offset as f32);
    }

    #[test]
    fn offsets_are_contiguous() {
        let spec = full_spec(&presets::tiny_mamba2());
        let mut expect = 0usize;
        for e in &spec.entries {
            assert_eq!(e.offset, expect, "{}", e.name);
            expect += e.shape.iter().product::<usize>();
        }
        assert_eq!(expect, spec.total());
    }
}
