//! Quality evaluation — the Table-1 substitute (DESIGN.md §1).
//!
//! The paper measures PLU-approximation quality on LAMBADA/HellaSwag/...
//! via pretrained HF checkpoints; offline we measure the same causal
//! chain — activation approximation -> logit divergence -> task-metric
//! delta — on the trained tiny char-LMs over held-out synthetic corpus:
//! next-byte perplexity, top-1 accuracy, and logit drift vs the exact
//! model, for exact vs PLU-8/16/32 variants.

use crate::config::ModelShape;
use crate::exec::{Backend, Plan, PlannedBackend};
use crate::graph::{Graph, Tensor};
use crate::models::params::{full_spec, ParamSpec};

/// LM-quality measurement over held-out text.
#[derive(Clone, Debug)]
pub struct QualityReport {
    /// Next-byte perplexity (e^mean-NLL) — Table 1's "PPL ↓" analogue.
    pub ppl: f64,
    /// Next-byte top-1 accuracy — Table 1's "ACC ↑" analogue.
    pub top1: f64,
    /// Mean |logit - exact_logit| (0 for the exact variant itself).
    pub logit_mae: f64,
    /// Max |logit - exact_logit|.
    pub logit_max: f64,
    pub windows: usize,
}

/// Slice every parameter out of the flat weights buffer, graph-input order.
pub fn param_inputs(spec: &ParamSpec, buf: &[f32]) -> Vec<Tensor> {
    spec.entries
        .iter()
        .map(|e| {
            let size: usize = e.shape.iter().product();
            Tensor::f32(e.shape.clone(), buf[e.offset..e.offset + size].to_vec())
        })
        .collect()
}

fn log_softmax_nll(logits: &[f32], target: usize) -> (f64, bool) {
    let mx = logits.iter().cloned().fold(f32::MIN, f32::max);
    let lse: f64 = logits.iter().map(|&l| ((l - mx) as f64).exp()).sum::<f64>().ln()
        + mx as f64;
    let nll = lse - logits[target] as f64;
    let argmax = logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0;
    (nll, argmax == target)
}

/// Evaluate a prefill graph (tokens -> all logits) as a byte LM over
/// sliding windows of `text`. `exact_logits` (if given) must be the
/// per-window logits of the exact model for divergence metrics.
pub fn eval_lm(
    shape: &ModelShape,
    graph: &Graph,
    weights: &[f32],
    text: &[u8],
    window: usize,
    max_windows: usize,
    exact_logits: Option<&[Vec<f32>]>,
) -> (QualityReport, Vec<Vec<f32>>) {
    let spec = full_spec(shape);
    assert_eq!(spec.total(), weights.len(), "weights/spec mismatch");
    let params = param_inputs(&spec, weights);
    let stride = window; // non-overlapping windows
    let mut nll_sum = 0.0f64;
    let mut nll_n = 0usize;
    let mut hits = 0usize;
    let mut mae_sum = 0.0f64;
    let mut mae_n = 0usize;
    let mut max_err = 0.0f64;
    let mut all_logits: Vec<Vec<f32>> = Vec::new();

    let mut windows = 0usize;
    let mut start = 0usize;
    // params are hoisted: only the token tensor changes per window
    // (EXPERIMENTS.md §Perf iteration 5); the plan is compiled once and
    // its arena reused across every window
    let mut inputs = params;
    inputs.push(Tensor::i32(vec![window], vec![0; window]));
    let mut plan = PlannedBackend.plan(graph).expect("plan compiles");
    while windows < max_windows && start + window + 1 <= text.len() {
        let tokens: Vec<i32> =
            text[start..start + window].iter().map(|&b| b as i32).collect();
        let n = inputs.len();
        inputs[n - 1] = Tensor::i32(vec![window], tokens);
        let out = plan.execute(&inputs).expect("planned eval");
        let logits = out[0].as_f32(); // (T, V)
        let v = shape.vocab_size;
        for t in 0..window - 1 {
            let target = text[start + t + 1] as usize;
            let row = &logits[t * v..(t + 1) * v];
            let (nll, hit) = log_softmax_nll(row, target);
            nll_sum += nll;
            nll_n += 1;
            hits += usize::from(hit);
        }
        if let Some(exact) = exact_logits {
            let er = &exact[windows];
            for (a, b) in logits.iter().zip(er) {
                let d = (*a as f64 - *b as f64).abs();
                mae_sum += d;
                max_err = max_err.max(d);
            }
            mae_n += logits.len();
        }
        all_logits.push(logits.to_vec());
        windows += 1;
        start += stride;
    }
    (
        QualityReport {
            ppl: (nll_sum / nll_n.max(1) as f64).exp(),
            top1: hits as f64 / nll_n.max(1) as f64,
            logit_mae: if mae_n == 0 { 0.0 } else { mae_sum / mae_n as f64 },
            logit_max: max_err,
            windows,
        },
        all_logits,
    )
}

/// In-context recall ("induction-head") probe: a sentence shown twice in
/// the window should be easier to predict on its second occurrence. SSMs
/// carry context in their recurrent state; this measures whether the
/// trained model (and its PLU approximation) actually uses it. Returns
/// (first-pass accuracy, second-pass accuracy).
pub fn induction_probe(
    shape: &ModelShape,
    graph: &Graph,
    weights: &[f32],
    window: usize,
    trials: usize,
    seed: u64,
) -> (f64, f64) {
    let spec = full_spec(shape);
    let params = param_inputs(&spec, weights);
    let mut rng = crate::util::Prng::new(seed);
    let mut plan = PlannedBackend.plan(graph).expect("plan compiles");
    let (mut hit1, mut n1, mut hit2, mut n2) = (0usize, 0usize, 0usize, 0usize);
    for _ in 0..trials {
        // window = [pad][sentence][sentence]; compare accuracy per copy
        let s = crate::util::corpus::sentence(&mut rng);
        let sb = s.as_bytes();
        let need = 2 * sb.len();
        if need + 1 > window {
            continue;
        }
        let mut text = vec![b' '; window - need];
        text.extend_from_slice(sb);
        text.extend_from_slice(sb);
        let tokens: Vec<i32> = text.iter().map(|&b| b as i32).collect();
        let mut inputs = params.clone();
        inputs.push(Tensor::i32(vec![window], tokens));
        let out = plan.execute(&inputs).expect("planned eval");
        let logits = out[0].as_f32();
        let v = shape.vocab_size;
        let first_start = window - need;
        for t in 0..window - 1 {
            let target = text[t + 1] as usize;
            if t + 1 <= first_start + 1 {
                continue; // padding region
            }
            let row = &logits[t * v..(t + 1) * v];
            let (_, hit) = log_softmax_nll(row, target);
            if t + 1 < first_start + sb.len() {
                hit1 += usize::from(hit);
                n1 += 1;
            } else if t + 1 >= first_start + sb.len() {
                hit2 += usize::from(hit);
                n2 += 1;
            }
        }
    }
    (
        hit1 as f64 / n1.max(1) as f64,
        hit2 as f64 / n2.max(1) as f64,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nll_math_is_sane() {
        // peaked logits on the target: near-zero NLL, hit
        let mut l = vec![0.0f32; 4];
        l[2] = 20.0;
        let (nll, hit) = log_softmax_nll(&l, 2);
        assert!(nll < 1e-3 && hit);
        let (nll_miss, hit_miss) = log_softmax_nll(&l, 0);
        assert!(nll_miss > 10.0 && !hit_miss);
    }

    #[test]
    fn uniform_logits_give_vocab_ppl() {
        let l = vec![0.0f32; 256];
        let (nll, _) = log_softmax_nll(&l, 7);
        assert!((nll - (256f64).ln()).abs() < 1e-6);
    }
}
