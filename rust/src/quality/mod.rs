//! Quality evaluation — the Table-1 substitute (DESIGN.md §1).
//!
//! The paper measures PLU-approximation quality on LAMBADA/HellaSwag/...
//! via pretrained HF checkpoints; offline we measure the same causal
//! chain — activation approximation -> logit divergence -> task-metric
//! delta — on the trained tiny char-LMs over held-out synthetic corpus:
//! next-byte perplexity, top-1 accuracy, and logit drift vs the exact
//! model, for exact vs PLU-8/16/32 variants.

use std::sync::Arc;

use crate::config::ModelShape;
use crate::exec::{ExecJob, PlanCache, WorkerPool};
use crate::graph::tensor::DType;
use crate::graph::{Graph, Tensor};
use crate::models::params::{full_spec, ParamSpec};
use crate::passes::quantize;

/// LM-quality measurement over held-out text.
#[derive(Clone, Debug)]
pub struct QualityReport {
    /// Next-byte perplexity (e^mean-NLL) — Table 1's "PPL ↓" analogue.
    pub ppl: f64,
    /// Next-byte top-1 accuracy — Table 1's "ACC ↑" analogue.
    pub top1: f64,
    /// Mean |logit - exact_logit| (0 for the exact variant itself).
    pub logit_mae: f64,
    /// Max |logit - exact_logit|.
    pub logit_max: f64,
    pub windows: usize,
}

/// Slice every parameter out of the flat weights buffer, graph-input order.
pub fn param_inputs(spec: &ParamSpec, buf: &[f32]) -> Vec<Tensor> {
    spec.entries
        .iter()
        .map(|e| {
            let size: usize = e.shape.iter().product();
            Tensor::f32(e.shape.clone(), buf[e.offset..e.offset + size].to_vec())
        })
        .collect()
}

fn log_softmax_nll(logits: &[f32], target: usize) -> (f64, bool) {
    let mx = logits.iter().cloned().fold(f32::MIN, f32::max);
    let lse: f64 = logits.iter().map(|&l| ((l - mx) as f64).exp()).sum::<f64>().ln()
        + mx as f64;
    let nll = lse - logits[target] as f64;
    let argmax = logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0;
    (nll, argmax == target)
}

/// Execute a prefill graph over many token windows, either serially
/// (one cached plan, arena reused per window) or data-parallel across a
/// [`WorkerPool`] (`workers > 1`; each worker compiles its own plan).
/// Results come back in window order, so the two paths — and every
/// worker count — produce bitwise-identical logits.
fn run_windows(
    graph: &Graph,
    params: Vec<Tensor>,
    window: usize,
    token_windows: Vec<Vec<i32>>,
    workers: usize,
) -> Result<Vec<Vec<f32>>, String> {
    let shared = Arc::new(params);
    if workers <= 1 || token_windows.len() <= 1 {
        // params are hoisted: only the token tensor changes per window
        // (EXPERIMENTS.md §Perf iteration 5); the plan is compiled once
        // and its arena reused across every window
        let mut cache = PlanCache::new();
        cache.insert_with("eval", graph, &shared)?;
        token_windows
            .into_iter()
            .map(|toks| {
                let out = cache.run("eval", vec![Tensor::i32(vec![window], toks)])?;
                Ok(out[0].as_f32().to_vec())
            })
            .collect()
    } else {
        let pool = WorkerPool::new(workers.min(token_windows.len()));
        let g = Arc::new(graph.clone());
        let jobs: Vec<ExecJob> = token_windows
            .into_iter()
            .map(|toks| ExecJob {
                graph: g.clone(),
                key: "eval".into(),
                shared: shared.clone(),
                tail: vec![Tensor::i32(vec![window], toks)],
            })
            .collect();
        pool.execute_batch(jobs)
            .into_iter()
            .map(|r| r.map(|outs| outs[0].as_f32().to_vec()))
            .collect()
    }
}

/// Evaluate a prefill graph (tokens -> all logits) as a byte LM over
/// sliding windows of `text`. `exact_logits` (if given) must be the
/// per-window logits of the exact model for divergence metrics.
/// `workers > 1` evaluates windows data-parallel on an execution pool;
/// the report is bitwise-independent of the worker count.
pub fn eval_lm(
    shape: &ModelShape,
    graph: &Graph,
    weights: &[f32],
    text: &[u8],
    window: usize,
    max_windows: usize,
    exact_logits: Option<&[Vec<f32>]>,
    workers: usize,
) -> Result<(QualityReport, Vec<Vec<f32>>), String> {
    eval_lm_dtyped(
        shape,
        graph,
        weights,
        DType::F32,
        text,
        window,
        max_windows,
        exact_logits,
        workers,
    )
}

/// [`eval_lm`] at an explicit serving dtype: the graph goes through
/// `passes::quantize` (the same pipeline `xamba serve --dtype` uses) and
/// the f32 weights are converted to the planned per-weight dtypes before
/// evaluation. Pass the f32 run's logits as `exact_logits` to have the
/// report carry the quantization-induced logit drift — the accuracy
/// delta the `--dtype` flag trades for latency.
#[allow(clippy::too_many_arguments)]
pub fn eval_lm_dtyped(
    shape: &ModelShape,
    graph: &Graph,
    weights: &[f32],
    dtype: DType,
    text: &[u8],
    window: usize,
    max_windows: usize,
    exact_logits: Option<&[Vec<f32>]>,
    workers: usize,
) -> Result<(QualityReport, Vec<Vec<f32>>), String> {
    let spec = full_spec(shape);
    if spec.total() != weights.len() {
        return Err(format!(
            "weights/spec mismatch: {} vs {} for {}",
            weights.len(),
            spec.total(),
            shape.name
        ));
    }
    let mut quantized: Option<Graph> = None;
    let params = if dtype == DType::F32 {
        param_inputs(&spec, weights)
    } else {
        let wd = quantize::plan_weight_dtypes(graph, spec.entries.len(), dtype);
        quantized = Some(quantize::quantize_graph(graph, dtype, &wd)?);
        param_inputs(&spec, weights)
            .into_iter()
            .zip(&wd)
            .map(|(t, &d)| if t.dtype() == d { t } else { t.to_dtype(d) })
            .collect()
    };
    let graph = quantized.as_ref().unwrap_or(graph);
    let stride = window; // non-overlapping windows
    let mut starts: Vec<usize> = Vec::new();
    let mut start = 0usize;
    while starts.len() < max_windows && start + window + 1 <= text.len() {
        starts.push(start);
        start += stride;
    }
    let token_windows: Vec<Vec<i32>> = starts
        .iter()
        .map(|&s| text[s..s + window].iter().map(|&b| b as i32).collect())
        .collect();
    let all_logits = run_windows(graph, params, window, token_windows, workers)?;

    let mut nll_sum = 0.0f64;
    let mut nll_n = 0usize;
    let mut hits = 0usize;
    let mut mae_sum = 0.0f64;
    let mut mae_n = 0usize;
    let mut max_err = 0.0f64;
    let v = shape.vocab_size;
    for (wi, (&s, logits)) in starts.iter().zip(&all_logits).enumerate() {
        for t in 0..window - 1 {
            let target = text[s + t + 1] as usize;
            let row = &logits[t * v..(t + 1) * v];
            let (nll, hit) = log_softmax_nll(row, target);
            nll_sum += nll;
            nll_n += 1;
            hits += usize::from(hit);
        }
        if let Some(exact) = exact_logits {
            let er = &exact[wi];
            for (a, b) in logits.iter().zip(er) {
                let d = (*a as f64 - *b as f64).abs();
                mae_sum += d;
                max_err = max_err.max(d);
            }
            mae_n += logits.len();
        }
    }
    Ok((
        QualityReport {
            ppl: (nll_sum / nll_n.max(1) as f64).exp(),
            top1: hits as f64 / nll_n.max(1) as f64,
            logit_mae: if mae_n == 0 { 0.0 } else { mae_sum / mae_n as f64 },
            logit_max: max_err,
            windows: starts.len(),
        },
        all_logits,
    ))
}

/// In-context recall ("induction-head") probe: a sentence shown twice in
/// the window should be easier to predict on its second occurrence. SSMs
/// carry context in their recurrent state; this measures whether the
/// trained model (and its PLU approximation) actually uses it. Returns
/// (first-pass accuracy, second-pass accuracy).
pub fn induction_probe(
    shape: &ModelShape,
    graph: &Graph,
    weights: &[f32],
    window: usize,
    trials: usize,
    seed: u64,
    workers: usize,
) -> Result<(f64, f64), String> {
    let spec = full_spec(shape);
    if spec.total() != weights.len() {
        return Err(format!(
            "weights/spec mismatch: {} vs {} for {}",
            weights.len(),
            spec.total(),
            shape.name
        ));
    }
    let params = param_inputs(&spec, weights);
    let mut rng = crate::util::Prng::new(seed);
    // draw every trial window up front (rng order is execution-
    // independent), then evaluate serial or data-parallel
    let mut texts: Vec<(Vec<u8>, usize)> = Vec::new(); // (window text, |sentence|)
    for _ in 0..trials {
        // window = [pad][sentence][sentence]; compare accuracy per copy
        let s = crate::util::corpus::sentence(&mut rng);
        let sb = s.as_bytes();
        let need = 2 * sb.len();
        if need + 1 > window {
            continue;
        }
        let mut text = vec![b' '; window - need];
        text.extend_from_slice(sb);
        text.extend_from_slice(sb);
        texts.push((text, sb.len()));
    }
    let token_windows: Vec<Vec<i32>> = texts
        .iter()
        .map(|(text, _)| text.iter().map(|&b| b as i32).collect())
        .collect();
    let all_logits = run_windows(graph, params, window, token_windows, workers)?;

    let (mut hit1, mut n1, mut hit2, mut n2) = (0usize, 0usize, 0usize, 0usize);
    let v = shape.vocab_size;
    for ((text, slen), logits) in texts.iter().zip(&all_logits) {
        let need = 2 * slen;
        let first_start = window - need;
        for t in 0..window - 1 {
            let target = text[t + 1] as usize;
            if t + 1 <= first_start + 1 {
                continue; // padding region
            }
            let row = &logits[t * v..(t + 1) * v];
            let (_, hit) = log_softmax_nll(row, target);
            if t + 1 < first_start + slen {
                hit1 += usize::from(hit);
                n1 += 1;
            } else {
                hit2 += usize::from(hit);
                n2 += 1;
            }
        }
    }
    Ok((
        hit1 as f64 / n1.max(1) as f64,
        hit2 as f64 / n2.max(1) as f64,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nll_math_is_sane() {
        // peaked logits on the target: near-zero NLL, hit
        let mut l = vec![0.0f32; 4];
        l[2] = 20.0;
        let (nll, hit) = log_softmax_nll(&l, 2);
        assert!(nll < 1e-3 && hit);
        let (nll_miss, hit_miss) = log_softmax_nll(&l, 0);
        assert!(nll_miss > 10.0 && !hit_miss);
    }

    #[test]
    fn uniform_logits_give_vocab_ppl() {
        let l = vec![0.0f32; 256];
        let (nll, _) = log_softmax_nll(&l, 7);
        assert!((nll - (256f64).ln()).abs() < 1e-6);
    }

    #[test]
    fn eval_lm_rejects_bad_weights_len() {
        let shape = crate::config::presets::tiny_mamba();
        let g = crate::models::build_prefill(&shape, 8);
        let r = eval_lm(&shape, &g, &[0.0; 3], b"hello world hello", 8, 1, None, 1);
        assert!(r.unwrap_err().contains("weights/spec mismatch"));
    }

    #[test]
    fn eval_lm_is_bitwise_identical_across_worker_counts() {
        let shape = crate::config::presets::tiny_mamba();
        let window = 16usize;
        let g = crate::models::build_prefill(&shape, window);
        let spec = full_spec(&shape);
        let mut rng = crate::util::Prng::new(5);
        let weights = rng.range_vec(spec.total(), -0.1, 0.1);
        let text = crate::util::corpus::corpus(200, 99);
        let (rep1, logits1) =
            eval_lm(&shape, &g, &weights, &text, window, 3, None, 1).unwrap();
        let (rep4, logits4) =
            eval_lm(&shape, &g, &weights, &text, window, 3, None, 4).unwrap();
        assert_eq!(logits1, logits4, "pooled eval diverged from serial");
        assert_eq!(rep1.ppl.to_bits(), rep4.ppl.to_bits());
        assert_eq!(rep1.windows, 3);
    }

    #[test]
    fn eval_lm_mamba2_is_bitwise_identical_across_worker_counts() {
        // the mamba-2 prefill graph (chunked SSD, CumSum_b, ReduceSum)
        // must evaluate data-parallel on the pool exactly like mamba-1
        let shape = crate::config::presets::tiny_mamba2();
        let window = 16usize;
        let g = crate::models::build_prefill(&shape, window);
        let spec = full_spec(&shape);
        let mut rng = crate::util::Prng::new(6);
        let weights = rng.range_vec(spec.total(), -0.1, 0.1);
        let text = crate::util::corpus::corpus(200, 77);
        let (rep1, logits1) =
            eval_lm(&shape, &g, &weights, &text, window, 3, None, 1).unwrap();
        let (rep4, logits4) =
            eval_lm(&shape, &g, &weights, &text, window, 3, None, 4).unwrap();
        assert_eq!(logits1, logits4, "pooled mamba-2 eval diverged from serial");
        assert_eq!(rep1.ppl.to_bits(), rep4.ppl.to_bits());
        assert!(rep1.ppl.is_finite());
    }

    #[test]
    fn eval_lm_dtyped_reports_the_quantization_delta() {
        let shape = crate::config::presets::tiny_mamba();
        let window = 16usize;
        let g = crate::models::build_prefill(&shape, window);
        let spec = full_spec(&shape);
        let mut rng = crate::util::Prng::new(11);
        let weights = rng.range_vec(spec.total(), -0.1, 0.1);
        let text = crate::util::corpus::corpus(200, 42);
        let (exact, logits) =
            eval_lm(&shape, &g, &weights, &text, window, 2, None, 1).unwrap();
        for dtype in [DType::F16, DType::I8] {
            let (rep, _) = eval_lm_dtyped(
                &shape,
                &g,
                &weights,
                dtype,
                &text,
                window,
                2,
                Some(&logits),
                1,
            )
            .unwrap();
            assert!(rep.ppl.is_finite(), "{dtype:?} ppl");
            // f32-vs-quantized drift is recorded and small on a tiny net
            assert!(rep.logit_max > 0.0, "{dtype:?} must drift a little");
            let rel = (rep.ppl - exact.ppl).abs() / exact.ppl;
            assert!(rel < 0.1, "{dtype:?} ppl {} vs f32 {}", rep.ppl, exact.ppl);
        }
        // f16 is a strictly finer approximation than i8 here
        let (rep16, _) = eval_lm_dtyped(
            &shape, &g, &weights, DType::F16, &text, window, 2, Some(&logits), 1,
        )
        .unwrap();
        let (rep8, _) = eval_lm_dtyped(
            &shape, &g, &weights, DType::I8, &text, window, 2, Some(&logits), 1,
        )
        .unwrap();
        assert!(rep16.logit_mae <= rep8.logit_mae);
    }

    #[test]
    fn induction_probe_runs_mamba2_on_the_pool() {
        let shape = crate::config::presets::tiny_mamba2();
        // >= 2*max-sentence+1 (~85) so every trial window actually scores
        let window = 96usize;
        let g = crate::models::build_prefill(&shape, window);
        let spec = full_spec(&shape);
        let mut rng = crate::util::Prng::new(8);
        let weights = rng.range_vec(spec.total(), -0.1, 0.1);
        let serial = induction_probe(&shape, &g, &weights, window, 2, 123, 1).unwrap();
        let pooled = induction_probe(&shape, &g, &weights, window, 2, 123, 2).unwrap();
        assert_eq!(serial, pooled, "probe diverged across worker counts");
        assert!(serial.0.is_finite() && serial.1.is_finite());
    }
}
