//! XAMBA — State-Space Models on resource-constrained NPUs, reproduced.
//!
//! Rust + JAX + Pallas three-layer reproduction of *"XAMBA: Enabling
//! Efficient State Space Models on Resource-Constrained Neural Processing
//! Units"*. Layer 3 (this crate) hosts the serving coordinator, the
//! compiler passes (CumBA / ReduBA / ActiBA), the NPU cost-model simulator
//! that substitutes for the paper's Intel Core Ultra Series 2 platform,
//! and the PJRT runtime that executes the AOT artifacts produced by the
//! python build path (`python/compile/`). See DESIGN.md for the map.

pub mod cli;
pub mod config;
pub mod coordinator;
pub mod exec;
pub mod npu;
pub mod passes;
pub mod graph;
pub mod interp;
pub mod models;
pub mod plu;
pub mod quality;
pub mod runtime;
pub mod util;
