//! ReduBA: rewrite ReduceSum into a ones-mask MVM (paper §2.1).
//!
//! `R[j] = Σ_i X[i,j]` equals `1_m @ X` — a matrix-vector product against
//! an all-ones mask. Unlike CumBA's (m x m) mask, the same length-m vector
//! is reused by every output element, so the mask adds O(m) traffic once;
//! the reduction itself moves from the DSP to the MPU's MAC array.
//!
//! Handles reductions along the last axis (`X @ 1`) and the second-to-last
//! axis (`1^T @ X`, batched); other axes are left sequential.

use crate::graph::{ConstKind, Graph, Op, Tensor};

use super::{rebuild, Pass};

/// The ReduBA rewrite pass.
#[derive(Clone, Copy, Debug, Default)]
pub struct RedubaPass;

impl Pass for RedubaPass {
    fn name(&self) -> &'static str {
        "reduba"
    }

    fn apply(&self, g: &Graph) -> Graph {
        rebuild(g, |out, node, remap| {
            let Op::ReduceSum { axis } = node.op else { return None };
            let x_old = node.inputs[0];
            let in_shape = g.shape(x_old).to_vec();
            let rank = in_shape.len();
            let x = remap(x_old);
            let nm = |s: &str| format!("{}.{s}", node.name);
            if axis == rank - 1 {
                // R = X @ 1 : (..., m, n) x (n, 1) -> (..., m, 1) -> drop
                let n = in_shape[rank - 1];
                let ones = out.constant_kind(
                    &nm("reduba_ones"),
                    Tensor::f32(vec![n, 1], vec![1.0; n]),
                    ConstKind::OnesMask,
                );
                let mm = out.matmul(x, ones, &nm("reduba"));
                Some(out.reshape(mm, node.shape.clone(), &nm("squeeze")))
            } else if rank >= 2 && axis == rank - 2 {
                // R = 1^T @ X : (1, m) x (..., m, n) -> (..., 1, n) -> drop
                let m = in_shape[rank - 2];
                let ones = out.constant_kind(
                    &nm("reduba_ones"),
                    Tensor::f32(vec![1, m], vec![1.0; m]),
                    ConstKind::OnesMask,
                );
                let mm = out.matmul(ones, x, &nm("reduba"));
                Some(out.reshape(mm, node.shape.clone(), &nm("squeeze")))
            } else {
                None
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Census, Graph, Tensor};
    use crate::interp;
    use crate::util::quickcheck::{assert_close, check};
    use crate::util::Prng;

    #[test]
    fn rewrites_row_reduction() {
        let mut g = Graph::new("t");
        let x = g.input("x", vec![6, 4]);
        let r = g.reduce_sum(x, 0, "rs");
        g.output(r);
        let g2 = RedubaPass.apply(&g);
        assert_eq!(Census::of(&g2).get("ReduceSum"), 0);
        assert_eq!(Census::of(&g2).get("MatMul"), 1);
        let mut rng = Prng::new(1);
        let xs = Tensor::f32(vec![6, 4], rng.normal_vec(24));
        let a = interp::run(&g, &[xs.clone()]).unwrap();
        let b = interp::run(&g2, &[xs]).unwrap();
        assert_eq!(a[0].shape, b[0].shape);
        assert_close(a[0].as_f32(), b[0].as_f32(), 1e-5, 1e-5).unwrap();
    }

    #[test]
    fn rewrites_last_axis_rank3() {
        // the cb.reducesum pattern: (Tc, Tc, N) along axis 2
        let mut g = Graph::new("t");
        let x = g.input("x", vec![5, 5, 7]);
        let r = g.reduce_sum(x, 2, "rs");
        g.output(r);
        let g2 = RedubaPass.apply(&g);
        assert_eq!(Census::of(&g2).get("ReduceSum"), 0);
        let mut rng = Prng::new(2);
        let xs = Tensor::f32(vec![5, 5, 7], rng.normal_vec(175));
        let a = interp::run(&g, &[xs.clone()]).unwrap();
        let b = interp::run(&g2, &[xs]).unwrap();
        assert_eq!(b[0].shape, vec![5, 5]);
        assert_close(a[0].as_f32(), b[0].as_f32(), 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn ones_mask_kind_set_for_reuse_modeling() {
        let mut g = Graph::new("t");
        let x = g.input("x", vec![3, 3]);
        let r = g.reduce_sum(x, 1, "rs");
        g.output(r);
        let g2 = RedubaPass.apply(&g);
        assert!(g2.nodes.iter().any(|n| matches!(
            n.op,
            crate::graph::Op::Const { kind: ConstKind::OnesMask }
        )));
    }

    #[test]
    fn property_equivalence_random_axis() {
        check(
            |r| (2 + r.below(6), 2 + r.below(6), r.below(2), r.next_u64()),
            |&(m, n, axis, seed)| {
                let mut g = Graph::new("p");
                let x = g.input("x", vec![m, n]);
                let r = g.reduce_sum(x, axis, "rs");
                g.output(r);
                let g2 = RedubaPass.apply(&g);
                if Census::of(&g2).get("ReduceSum") != 0 {
                    return Err("not rewritten".into());
                }
                let mut rng = Prng::new(seed);
                let xs = Tensor::f32(vec![m, n], rng.normal_vec(m * n));
                let a = interp::run(&g, &[xs.clone()]).map_err(|e| e)?;
                let b = interp::run(&g2, &[xs]).map_err(|e| e)?;
                assert_close(a[0].as_f32(), b[0].as_f32(), 1e-4, 1e-4)
            },
        );
    }
}
