//! ActiBA: map Swish/Softplus onto the drain-path PLU (paper §2.2).
//!
//! Replaces exact transcendental activation nodes with `Op::Plu` nodes
//! carrying a fitted C-LUT. When the producer is an MPU op the PLU
//! evaluates during the drain phase ("vertical fusion") — the cost model
//! then charges no extra memory traffic. This is the paper's step-3
//! accuracy-for-performance trade; the quality side is measured by the
//! Table-1 substitute bench.

use std::sync::Arc;

use crate::graph::{Graph, Op, UnKind};
use crate::plu::{self, PluTable};

use super::{rebuild, Pass};

/// The ActiBA rewrite pass; which activations to map is configurable so
/// the Fig-4(c) bench can apply Softplus-only, then Softplus+SiLU.
#[derive(Clone, Debug)]
pub struct ActibaPass {
    pub map_silu: bool,
    pub map_softplus: bool,
    pub silu_table: Arc<PluTable>,
    pub softplus_table: Arc<PluTable>,
}

impl Default for ActibaPass {
    fn default() -> Self {
        Self::with_segments(32)
    }
}

impl ActibaPass {
    /// Both activations mapped with `segments`-entry C-LUTs on [-8, 8].
    pub fn with_segments(segments: usize) -> Self {
        Self {
            map_silu: true,
            map_softplus: true,
            silu_table: Arc::new(plu::silu_table(segments, -8.0, 8.0)),
            softplus_table: Arc::new(plu::softplus_table(segments, -8.0, 8.0)),
        }
    }

    /// Softplus-only variant (the first step of Fig 4(c)).
    pub fn softplus_only(segments: usize) -> Self {
        Self { map_silu: false, ..Self::with_segments(segments) }
    }
}

impl Pass for ActibaPass {
    fn name(&self) -> &'static str {
        "actiba"
    }

    fn apply(&self, g: &Graph) -> Graph {
        rebuild(g, |out, node, remap| {
            let Op::Unary(kind) = node.op else { return None };
            let table = match kind {
                UnKind::SiLU if self.map_silu => self.silu_table.clone(),
                UnKind::Softplus if self.map_softplus => self.softplus_table.clone(),
                _ => return None,
            };
            let x = remap(node.inputs[0]);
            Some(out.plu(x, table, kind, &format!("{}.plu", node.name)))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Census, Graph, Tensor};
    use crate::interp;
    use crate::util::Prng;

    fn act_graph() -> Graph {
        let mut g = Graph::new("t");
        let x = g.input("x", vec![4, 8]);
        let w = g.input("w", vec![8, 8]);
        let m = g.matmul(x, w, "mm");
        let s = g.silu(m, "swish");
        let p = g.softplus(s, "softplus");
        g.output(p);
        g
    }

    #[test]
    fn replaces_both_activations() {
        let g2 = ActibaPass::default().apply(&act_graph());
        let c = Census::of(&g2);
        assert_eq!(c.get("Swish"), 0);
        assert_eq!(c.get("SoftPlus"), 0);
        assert_eq!(c.get("PLU"), 2);
    }

    #[test]
    fn softplus_only_leaves_silu() {
        let g2 = ActibaPass::softplus_only(32).apply(&act_graph());
        let c = Census::of(&g2);
        assert_eq!(c.get("Swish"), 1);
        assert_eq!(c.get("SoftPlus"), 0);
        assert_eq!(c.get("PLU"), 1);
    }

    #[test]
    fn approximation_error_within_lut_bound() {
        let g = act_graph();
        let g2 = ActibaPass::default().apply(&g);
        let mut rng = Prng::new(4);
        let xs = Tensor::f32(vec![4, 8], rng.normal_vec(32));
        let ws = Tensor::f32(vec![8, 8], rng.normal_vec(64));
        let exact = interp::run(&g, &[xs.clone(), ws.clone()]).unwrap();
        let approx = interp::run(&g2, &[xs, ws]).unwrap();
        // two chained 32-segment LUTs: error stays in the "negligible" regime
        let max_err = exact[0]
            .as_f32()
            .iter()
            .zip(approx[0].as_f32())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 0.05, "max_err {max_err}");
        assert!(max_err > 0.0, "suspiciously exact");
    }

    #[test]
    fn more_segments_reduce_model_error() {
        let g = act_graph();
        let mut rng = Prng::new(9);
        let xs = Tensor::f32(vec![4, 8], rng.normal_vec(32));
        let ws = Tensor::f32(vec![8, 8], rng.normal_vec(64));
        let exact = interp::run(&g, &[xs.clone(), ws.clone()]).unwrap();
        let mut errs = Vec::new();
        for seg in [8, 32, 128] {
            let g2 = ActibaPass::with_segments(seg).apply(&g);
            let approx = interp::run(&g2, &[xs.clone(), ws.clone()]).unwrap();
            let e: f32 = exact[0]
                .as_f32()
                .iter()
                .zip(approx[0].as_f32())
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            errs.push(e);
        }
        assert!(errs[0] > errs[1] && errs[1] > errs[2], "{errs:?}");
    }
}
