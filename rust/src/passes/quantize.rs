//! Dtype-propagation + quantize/dequantize-insertion pass — the
//! compile-time half of reduced-precision serving.
//!
//! Runs LAST in the pass pipeline (after CumBA / ReduBA / ActiBA), so
//! every XAMBA rewrite is preserved: the pass sees masked matmuls and
//! PLU nodes like any other op and retypes them in place.
//!
//! Two policies, one mechanism:
//!
//! * **f16** — the whole f32 body moves to f16 storage: weight inputs
//!   are redeclared f16 (the serving layer converts its parameter
//!   tensors once, halving resident weight bytes), f32 constants
//!   (including the CumBA/ReduBA 0/1 masks, which are exact in f16)
//!   convert in place, and every f32 compute node becomes f16. Kernels
//!   accumulate in f32 and round only at stores.
//! * **i8** — dynamic per-tensor symmetric quantization around the
//!   *weight matmuls* (the projection GEMMs that dominate decode):
//!   rank-2 weight inputs consumed exclusively by `MatMul` are
//!   redeclared i8, the activation side of each such matmul gets a
//!   `Quantize` node (one per activation value, shared by all its
//!   consumers), and the matmul itself accumulates exactly in i32 and
//!   emits f32. Everything else — conv, norms, the SSM scan chain, and
//!   the CumBA/ReduBA mask matmuls — stays f32, so scan arithmetic
//!   never quantizes.
//!
//! Both policies keep the external ABI stable where it matters: i32
//! token inputs and f32 activation/state inputs stay as declared (f16
//! graphs quantize them on entry), and any reduced-precision graph
//! output is dequantized back to f32 — the serving layer's state
//! plumbing is dtype-oblivious.

use std::collections::HashMap;

use crate::graph::tensor::DType;
use crate::graph::{Graph, NodeId, Op};

/// Decide the serving dtype of each of the first `n_weights` graph
/// inputs (the parameter prefix) under `dtype`. The decision is purely
/// structural, so every graph of one model family (prefill, decode
/// buckets, batched prefill length-classes) planning over the same
/// parameter list reaches the same assignment — the serving layer
/// converts its shared parameter tensors exactly once.
pub fn plan_weight_dtypes(g: &Graph, n_weights: usize, dtype: DType) -> Vec<DType> {
    assert!(n_weights <= g.inputs.len(), "weight prefix exceeds input count");
    let declared: Vec<DType> =
        g.inputs[..n_weights].iter().map(|&id| g.node(id).dtype).collect();
    match dtype {
        DType::F32 => declared,
        DType::F16 => declared
            .into_iter()
            .map(|d| if d == DType::F32 { DType::F16 } else { d })
            .collect(),
        DType::I8 => {
            // a weight quantizes iff it is a rank-2 f32 matrix consumed
            // by MatMul nodes only (a projection); unused weights stay
            // f32 so graphs that do use them elsewhere agree
            let mut consumers: HashMap<NodeId, (usize, bool)> = HashMap::new();
            for node in &g.nodes {
                for &i in &node.inputs {
                    let e = consumers.entry(i).or_insert((0, true));
                    e.0 += 1;
                    e.1 &= matches!(node.op, Op::MatMul);
                }
            }
            g.inputs[..n_weights]
                .iter()
                .map(|&id| {
                    let node = g.node(id);
                    let (uses, all_mm) = consumers.get(&id).copied().unwrap_or((0, false));
                    if node.dtype == DType::F32 && node.shape.len() == 2 && uses > 0 && all_mm
                    {
                        DType::I8
                    } else {
                        node.dtype
                    }
                })
                .collect()
        }
        DType::I32 => panic!("i32 is not a serving dtype"),
    }
}

/// Rewrite `g` for reduced-precision execution under `dtype`, with the
/// first `weight_dtypes.len()` inputs redeclared per `weight_dtypes`
/// (from [`plan_weight_dtypes`] — callers serving several graphs off one
/// parameter set pass the same plan to every graph). `DType::F32` is the
/// identity.
pub fn quantize_graph(
    g: &Graph,
    dtype: DType,
    weight_dtypes: &[DType],
) -> Result<Graph, String> {
    if dtype == DType::F32 {
        return Ok(g.clone());
    }
    if !matches!(dtype, DType::F16 | DType::I8) {
        return Err(format!("{} is not a quantization target", dtype.name()));
    }
    // the rewrite emits inputs in node order; the ABI only survives if
    // that matches the declared input order
    if g.inputs.windows(2).any(|w| w[0] >= w[1]) {
        return Err("quantize_graph needs inputs declared in node order".into());
    }
    let input_pos: HashMap<NodeId, usize> =
        g.inputs.iter().enumerate().map(|(k, &id)| (id, k)).collect();

    let mut out = Graph::new(&format!("{}.{}", g.name, dtype.name()));
    // consumer-visible mapping old id -> new id (an input that gained a
    // Quantize maps to the Quantize node, so consumers see one dtype)
    let mut map: Vec<NodeId> = Vec::with_capacity(g.nodes.len());
    // one Quantize/Dequantize per source value, shared by its consumers
    let mut quant_of: HashMap<NodeId, NodeId> = HashMap::new();
    let mut deq_of: HashMap<NodeId, NodeId> = HashMap::new();

    for node in &g.nodes {
        let new_id = match &node.op {
            Op::Input { .. } => {
                let pos = input_pos[&node.id];
                let want = weight_dtypes.get(pos).copied().unwrap_or(node.dtype);
                if want != node.dtype {
                    // weight redeclared at the serving dtype; the caller
                    // provides converted parameter tensors
                    out.input_dtype(&node.name, node.shape.clone(), want)
                } else {
                    let id = out.input_dtype(&node.name, node.shape.clone(), node.dtype);
                    if dtype == DType::F16 && node.dtype == DType::F32 {
                        // activation/state input keeps its f32 ABI and is
                        // narrowed on entry
                        out.quantize(id, DType::F16, &format!("{}.q", node.name))
                    } else {
                        id
                    }
                }
            }
            Op::Const { kind } => {
                let v = node
                    .value
                    .clone()
                    .ok_or_else(|| format!("const node {} without value", node.id))?;
                let v = if dtype == DType::F16 && v.dtype() == DType::F32 {
                    v.to_dtype(DType::F16)
                } else {
                    v
                };
                out.constant_kind(&node.name, v, *kind)
            }
            Op::MatMul if dtype == DType::I8 => {
                let a = map[node.inputs[0]];
                let b = map[node.inputs[1]];
                if out.node(a).dtype == DType::I8 || out.node(b).dtype == DType::I8 {
                    let aq = coerce_i8(&mut out, a, &mut quant_of);
                    let bq = coerce_i8(&mut out, b, &mut quant_of);
                    // builder rule: i8 x i8 emits f32
                    out.matmul(aq, bq, &node.name)
                } else {
                    copy_node(&mut out, node, &map, dtype)
                }
            }
            _ => {
                if dtype == DType::I8 {
                    // a quantized weight reached a non-matmul consumer
                    // (possible when the weight plan came from a sibling
                    // graph): widen it back explicitly — "explicitly i8
                    // already in the source graph" stays i8
                    let mut inputs: Vec<NodeId> =
                        node.inputs.iter().map(|&i| map[i]).collect();
                    for (k, x) in inputs.iter_mut().enumerate() {
                        if out.node(*x).dtype == DType::I8
                            && g.node(node.inputs[k]).dtype != DType::I8
                        {
                            *x = dequantize_cached(&mut out, *x, &mut deq_of);
                        }
                    }
                    copy_node_with_inputs(&mut out, node, inputs, node.dtype)
                } else {
                    copy_node(&mut out, node, &map, dtype)
                }
            }
        };
        map.push(new_id);
    }

    for &o in &g.outputs {
        let mo = map[o];
        let id = match out.node(mo).dtype {
            DType::F16 | DType::I8 => dequantize_cached(&mut out, mo, &mut deq_of),
            _ => mo,
        };
        out.output(id);
    }
    Ok(out)
}

/// Re-emit `node` with remapped inputs; in f16 mode every f32 node
/// dtype moves to f16 (operands are f16 by induction).
fn copy_node(out: &mut Graph, node: &crate::graph::Node, map: &[NodeId], dtype: DType) -> NodeId {
    let dt = if dtype == DType::F16 && node.dtype == DType::F32 {
        DType::F16
    } else {
        node.dtype
    };
    let inputs: Vec<NodeId> = node.inputs.iter().map(|&i| map[i]).collect();
    copy_node_with_inputs(out, node, inputs, dt)
}

fn copy_node_with_inputs(
    out: &mut Graph,
    node: &crate::graph::Node,
    inputs: Vec<NodeId>,
    dt: DType,
) -> NodeId {
    out.add_node(
        node.op.clone(),
        inputs,
        node.shape.clone(),
        dt,
        node.name.clone(),
        node.value.clone(),
    )
}

/// `x` as an i8 value: identity for i8, a (cached) `Quantize` for f32.
fn coerce_i8(out: &mut Graph, x: NodeId, cache: &mut HashMap<NodeId, NodeId>) -> NodeId {
    if out.node(x).dtype == DType::I8 {
        return x;
    }
    if let Some(&q) = cache.get(&x) {
        return q;
    }
    let name = format!("{}.q8", out.node(x).name);
    let q = out.quantize(x, DType::I8, &name);
    cache.insert(x, q);
    q
}

fn dequantize_cached(
    out: &mut Graph,
    x: NodeId,
    cache: &mut HashMap<NodeId, NodeId>,
) -> NodeId {
    if let Some(&d) = cache.get(&x) {
        return d;
    }
    let name = format!("{}.dq", out.node(x).name);
    let d = out.dequantize(x, &name);
    cache.insert(x, d);
    d
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec;
    use crate::graph::Tensor;

    /// tokens -> gather -> norm-ish mul -> matmul(W) -> +bias graph with a
    /// 2-input parameter prefix [W, bias] — the minimal serving shape.
    fn toy_graph() -> Graph {
        let mut g = Graph::new("toy");
        let w = g.input("w", vec![4, 3]);
        let bias = g.input("bias", vec![3]);
        let x = g.input("x", vec![2, 4]);
        let m = g.matmul(x, w, "proj");
        let y = g.add(m, bias, "biased");
        let s = g.silu(y, "act");
        g.output(s);
        g
    }

    #[test]
    fn f32_plan_is_identity() {
        let g = toy_graph();
        let wd = plan_weight_dtypes(&g, 2, DType::F32);
        assert_eq!(wd, vec![DType::F32, DType::F32]);
        let q = quantize_graph(&g, DType::F32, &wd).unwrap();
        assert_eq!(q.nodes.len(), g.nodes.len());
    }

    #[test]
    fn i8_plan_targets_matmul_only_rank2_weights() {
        let g = toy_graph();
        let wd = plan_weight_dtypes(&g, 2, DType::I8);
        // W is a rank-2 matmul-only weight -> i8; bias feeds an Add -> f32
        assert_eq!(wd, vec![DType::I8, DType::F32]);
    }

    #[test]
    fn f16_plan_converts_every_f32_weight() {
        let g = toy_graph();
        let wd = plan_weight_dtypes(&g, 2, DType::F16);
        assert_eq!(wd, vec![DType::F16, DType::F16]);
    }

    #[test]
    fn i8_graph_quantizes_the_activation_side_and_keeps_the_abi() {
        let g = toy_graph();
        let wd = plan_weight_dtypes(&g, 2, DType::I8);
        let q = quantize_graph(&g, DType::I8, &wd).unwrap();
        // ABI: same number of inputs, x still f32, tokens-free toy has no i32
        assert_eq!(q.inputs.len(), 3);
        assert_eq!(q.node(q.inputs[0]).dtype, DType::I8);
        assert_eq!(q.node(q.inputs[2]).dtype, DType::F32);
        // outputs stay f32
        for &o in &q.outputs {
            assert_eq!(q.node(o).dtype, DType::F32);
        }
        // exactly one Quantize was inserted (the activation side)
        let quants = q
            .nodes
            .iter()
            .filter(|n| matches!(n.op, Op::Quantize { .. }))
            .count();
        assert_eq!(quants, 1);

        // numerically close to the f32 graph on real tensors
        let wt = Tensor::f32(vec![4, 3], (0..12).map(|i| (i as f32) * 0.05 - 0.3).collect());
        let bt = Tensor::f32(vec![3], vec![0.1, -0.2, 0.3]);
        let xt = Tensor::f32(vec![2, 4], (0..8).map(|i| (i as f32) * 0.25 - 1.0).collect());
        let exact = exec::run_once(&g, &[wt.clone(), bt.clone(), xt.clone()]).unwrap();
        let quant = exec::run_once(
            &q,
            &[wt.to_dtype(DType::I8), bt.clone(), xt.clone()],
        )
        .unwrap();
        for (a, b) in exact[0].as_f32().iter().zip(quant[0].as_f32()) {
            assert!((a - b).abs() < 0.05, "exact {a} vs i8 {b}");
        }
        // and bitwise-identical between planned and naive execution
        let planned = exec::run_once(
            &q,
            &[wt.to_dtype(DType::I8), bt.clone(), xt.clone()],
        )
        .unwrap();
        let naive =
            exec::naive::run(&q, &[wt.to_dtype(DType::I8), bt, xt]).unwrap();
        assert_eq!(planned[0].as_f32(), naive[0].as_f32());
    }

    #[test]
    fn f16_graph_moves_the_body_to_f16_and_dequantizes_outputs() {
        let g = toy_graph();
        let wd = plan_weight_dtypes(&g, 2, DType::F16);
        let q = quantize_graph(&g, DType::F16, &wd).unwrap();
        assert_eq!(q.node(q.inputs[0]).dtype, DType::F16);
        assert_eq!(q.node(q.inputs[1]).dtype, DType::F16);
        // activation input keeps its f32 ABI
        assert_eq!(q.node(q.inputs[2]).dtype, DType::F32);
        for &o in &q.outputs {
            assert_eq!(q.node(o).dtype, DType::F32, "outputs widen back to f32");
        }
        // the compute body is f16
        let body_f16 = q
            .nodes
            .iter()
            .filter(|n| matches!(n.op, Op::MatMul | Op::Binary(_) | Op::Unary(_)))
            .all(|n| n.dtype == DType::F16);
        assert!(body_f16);

        let wt = Tensor::f32(vec![4, 3], (0..12).map(|i| (i as f32) * 0.05 - 0.3).collect());
        let bt = Tensor::f32(vec![3], vec![0.1, -0.2, 0.3]);
        let xt = Tensor::f32(vec![2, 4], (0..8).map(|i| (i as f32) * 0.25 - 1.0).collect());
        let exact = exec::run_once(&g, &[wt.clone(), bt.clone(), xt.clone()]).unwrap();
        let half = exec::run_once(
            &q,
            &[wt.to_dtype(DType::F16), bt.to_dtype(DType::F16), xt.clone()],
        )
        .unwrap();
        assert_eq!(half[0].dtype(), DType::F32);
        for (a, b) in exact[0].as_f32().iter().zip(half[0].as_f32()) {
            assert!((a - b).abs() < 2e-2, "exact {a} vs f16 {b}");
        }
    }

    #[test]
    fn tokens_and_masks_survive_quantization() {
        // gather + tril-mask matmul (the CumBA shape): tokens stay i32,
        // the mask matmul stays f32 under i8 (scans never quantize)
        let mut g = Graph::new("m");
        let emb = g.input("emb", vec![8, 4]);
        let toks = g.input_i32("tokens", vec![3]);
        let x = g.gather(emb, toks, "embed");
        let mask = g.const_tril("mask", 3);
        let cs = g.matmul(mask, x, "cumba.mm");
        g.output(cs);
        let wd = plan_weight_dtypes(&g, 1, DType::I8);
        // emb feeds Gather -> stays f32
        assert_eq!(wd, vec![DType::F32]);
        let q = quantize_graph(&g, DType::I8, &wd).unwrap();
        assert_eq!(q.node(q.inputs[1]).dtype, DType::I32);
        assert!(
            q.nodes.iter().all(|n| !matches!(n.op, Op::Quantize { .. })),
            "no weight quantized -> no quantize nodes"
        );
        // under f16 the same graph converts the mask const and gathers f16
        let wd16 = plan_weight_dtypes(&g, 1, DType::F16);
        let q16 = quantize_graph(&g, DType::F16, &wd16).unwrap();
        let mask_node = q16.nodes.iter().find(|n| n.name == "mask").unwrap();
        assert_eq!(mask_node.dtype, DType::F16);
        assert_eq!(mask_node.value.as_ref().unwrap().dtype(), DType::F16);
    }
}
