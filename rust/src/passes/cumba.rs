//! CumBA: rewrite sequential CumSum into a masked MatMul (paper §2.1).
//!
//! `C[i,j] = Σ_{k<=i} X[k,j]` equals `M @ X` with the compile-time
//! lower-triangular mask `M[i,k] = (k <= i)`. The rewrite moves the op
//! from the DSP's m-step sequential loop onto the MPU MAC array, where the
//! mask is ZVC-compressed (~50 % zeros) and zero MACs are skipped by the
//! sparsity bitmap (Fig 3) — both modeled by `npu::cost`.
//!
//! Handles CumSum along the second-to-last axis (`M @ X`, batched over
//! leading dims) and the last axis (`X @ M^T`). Other axes are left alone
//! (the models never produce them).

use crate::graph::{ConstKind, Graph, Op, Tensor};

use super::{rebuild, Pass};

/// The CumBA rewrite pass.
#[derive(Clone, Copy, Debug, Default)]
pub struct CumbaPass;

/// Dense lower-triangular mask tensor M[i,j] = (j <= i).
fn tril_tensor(n: usize) -> Tensor {
    let mut data = vec![0.0f32; n * n];
    for i in 0..n {
        for j in 0..=i {
            data[i * n + j] = 1.0;
        }
    }
    Tensor::f32(vec![n, n], data)
}

/// Upper-triangular mask M[i,j] = (i <= j) — tril transposed, used for
/// cumsum along the last axis.
fn triu_tensor(n: usize) -> Tensor {
    let mut data = vec![0.0f32; n * n];
    for i in 0..n {
        for j in i..n {
            data[i * n + j] = 1.0;
        }
    }
    Tensor::f32(vec![n, n], data)
}

impl Pass for CumbaPass {
    fn name(&self) -> &'static str {
        "cumba"
    }

    fn apply(&self, g: &Graph) -> Graph {
        rebuild(g, |out, node, remap| {
            let Op::CumSum { axis } = node.op else { return None };
            let rank = node.shape.len();
            let x = remap(node.inputs[0]);
            if rank >= 2 && axis == rank - 2 {
                // C = M @ X (batched over leading dims)
                let m = node.shape[axis];
                let mask = out.constant_kind(
                    &format!("{}.cumba_mask", node.name),
                    tril_tensor(m),
                    ConstKind::TrilMask,
                );
                Some(out.matmul(mask, x, &format!("{}.cumba", node.name)))
            } else if rank >= 2 && axis == rank - 1 {
                // C = X @ M^T (mask transposed = upper triangular)
                let n = node.shape[axis];
                let mask = out.constant_kind(
                    &format!("{}.cumba_maskT", node.name),
                    triu_tensor(n),
                    ConstKind::TrilMask,
                );
                Some(out.matmul(x, mask, &format!("{}.cumba", node.name)))
            } else if rank == 1 {
                // vector cumsum: (1, n) @ M^T shaped via reshape
                let n = node.shape[0];
                let row = out.reshape(x, vec![1, n], &format!("{}.row", node.name));
                let mask = out.constant_kind(
                    &format!("{}.cumba_maskT", node.name),
                    triu_tensor(n),
                    ConstKind::TrilMask,
                );
                let mm = out.matmul(row, mask, &format!("{}.cumba", node.name));
                Some(out.reshape(mm, vec![n], &format!("{}.flat", node.name)))
            } else {
                None // unusual axis: keep the sequential op
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Census, Graph, Tensor};
    use crate::interp;
    use crate::util::quickcheck::{assert_close, check};
    use crate::util::Prng;

    fn run_both(g: &Graph, g2: &Graph, inputs: &[Tensor]) -> (Vec<f32>, Vec<f32>) {
        let a = interp::run(g, inputs).unwrap();
        let b = interp::run(g2, inputs).unwrap();
        (a[0].as_f32().to_vec(), b[0].as_f32().to_vec())
    }

    #[test]
    fn rewrites_rank2_axis0() {
        let mut g = Graph::new("t");
        let x = g.input("x", vec![5, 3]);
        let c = g.cumsum(x, 0, "cs");
        g.output(c);
        let g2 = CumbaPass.apply(&g);
        assert_eq!(Census::of(&g2).get("CumSum"), 0);
        assert_eq!(Census::of(&g2).get("MatMul"), 1);
        let mut rng = Prng::new(1);
        let xs = Tensor::f32(vec![5, 3], rng.normal_vec(15));
        let (a, b) = run_both(&g, &g2, &[xs]);
        assert_close(&a, &b, 1e-5, 1e-5).unwrap();
    }

    #[test]
    fn rewrites_rank3_middle_axis_batched() {
        // the CumSum_b pattern: (H, T, T) along axis 1
        let mut g = Graph::new("t");
        let x = g.input("x", vec![3, 8, 8]);
        let c = g.cumsum(x, 1, "cumsum_b");
        g.output(c);
        let g2 = CumbaPass.apply(&g);
        assert_eq!(Census::of(&g2).get("CumSum"), 0);
        let mut rng = Prng::new(2);
        let xs = Tensor::f32(vec![3, 8, 8], rng.normal_vec(192));
        let (a, b) = run_both(&g, &g2, &[xs]);
        assert_close(&a, &b, 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn rewrites_last_axis_and_vector() {
        let mut g = Graph::new("t");
        let x = g.input("x", vec![4, 6]);
        let c = g.cumsum(x, 1, "cs_last");
        g.output(c);
        let v = g.input("v", vec![7]);
        let cv = g.cumsum(v, 0, "cs_vec");
        g.output(cv);
        let g2 = CumbaPass.apply(&g);
        assert_eq!(Census::of(&g2).get("CumSum"), 0);
        let mut rng = Prng::new(3);
        let xs = Tensor::f32(vec![4, 6], rng.normal_vec(24));
        let vs = Tensor::f32(vec![7], rng.normal_vec(7));
        let a = interp::run(&g, &[xs.clone(), vs.clone()]).unwrap();
        let b = interp::run(&g2, &[xs, vs]).unwrap();
        assert_close(a[0].as_f32(), b[0].as_f32(), 1e-5, 1e-5).unwrap();
        assert_close(a[1].as_f32(), b[1].as_f32(), 1e-5, 1e-5).unwrap();
    }

    #[test]
    fn property_equivalence_random_shapes() {
        check(
            |r| (2 + r.below(12), 1 + r.below(8), r.next_u64()),
            |&(m, n, seed)| {
                let mut g = Graph::new("p");
                let x = g.input("x", vec![m, n]);
                let c = g.cumsum(x, 0, "cs");
                g.output(c);
                let g2 = CumbaPass.apply(&g);
                let mut rng = Prng::new(seed);
                let xs = Tensor::f32(vec![m, n], rng.normal_vec(m * n));
                let a = interp::run(&g, &[xs.clone()]).map_err(|e| e)?;
                let b = interp::run(&g2, &[xs]).map_err(|e| e)?;
                assert_close(a[0].as_f32(), b[0].as_f32(), 1e-4, 1e-4)
            },
        );
    }

    #[test]
    fn mask_is_marked_for_sparsity() {
        let mut g = Graph::new("t");
        let x = g.input("x", vec![4, 4]);
        let c = g.cumsum(x, 0, "cs");
        g.output(c);
        let g2 = CumbaPass.apply(&g);
        assert!(g2.nodes.iter().any(|n| matches!(
            n.op,
            crate::graph::Op::Const { kind: ConstKind::TrilMask }
        )));
    }
}
