//! Conversion-time graph rewrites — XAMBA's three optimizations.
//!
//! The paper applies CumBA / ReduBA / ActiBA "during conversion" of the
//! model to the NPU binary; here they are compiler passes over the IR:
//!
//! * [`cumba::CumbaPass`]   — CumSum -> masked MatMul on the MPU (§2.1)
//! * [`reduba::RedubaPass`] — ReduceSum -> ones-mask MVM on the MPU (§2.1)
//! * [`actiba::ActibaPass`] — Swish/Softplus -> drain-path PLU (§2.2)
//!
//! Every pass is verified by randomized differential testing against the
//! reference interpreter ([`verify`]): exact rewrites must agree to float
//! tolerance, ActiBA within its PLU error bound.

pub mod actiba;
pub mod cumba;
pub mod quantize;
pub mod reduba;
pub mod verify;

use crate::graph::{Graph, Node, NodeId};

/// A graph-to-graph rewrite.
pub trait Pass {
    fn name(&self) -> &'static str;
    fn apply(&self, g: &Graph) -> Graph;
}

/// Apply passes in order, returning the final graph and the per-pass
/// live-node deltas (for reports).
pub fn run_pipeline(g: &Graph, passes: &[&dyn Pass]) -> (Graph, Vec<(String, usize)>) {
    let mut cur = g.clone();
    let mut log = Vec::new();
    for p in passes {
        cur = p.apply(&cur);
        log.push((p.name().to_string(), cur.live_count()));
    }
    (cur, log)
}

/// Rebuild a graph node-by-node. For each old node, `rewrite` may emit a
/// replacement subgraph into `out` (returning the substitute id) or return
/// `None` to copy the node verbatim (with inputs remapped). Keeps the
/// topological id order, so interpreter and profiler work unchanged.
pub fn rebuild(
    g: &Graph,
    mut rewrite: impl FnMut(&mut Graph, &Node, &dyn Fn(NodeId) -> NodeId) -> Option<NodeId>,
) -> Graph {
    let mut out = Graph::new(&g.name);
    let mut map: Vec<NodeId> = Vec::with_capacity(g.nodes.len());
    for node in &g.nodes {
        let remap = |id: NodeId| map[id];
        let new_id = match rewrite(&mut out, node, &remap) {
            Some(id) => id,
            None => {
                let inputs: Vec<NodeId> = node.inputs.iter().map(|&i| map[i]).collect();
                out.add_node(
                    node.op.clone(),
                    inputs,
                    node.shape.clone(),
                    node.dtype,
                    node.name.clone(),
                    node.value.clone(),
                )
            }
        };
        map.push(new_id);
    }
    out.inputs = g.inputs.iter().map(|&i| map[i]).collect();
    out.outputs = g.outputs.iter().map(|&i| map[i]).collect();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Graph, Tensor};
    use crate::interp;

    #[test]
    fn identity_rebuild_preserves_semantics() {
        let mut g = Graph::new("t");
        let a = g.input("a", vec![2, 3]);
        let b = g.input("b", vec![3, 2]);
        let m = g.matmul(a, b, "m");
        let s = g.silu(m, "s");
        g.output(s);
        let g2 = rebuild(&g, |_, _, _| None);
        let xa = Tensor::f32(vec![2, 3], vec![1., -1., 2., 0.5, 0., 3.]);
        let xb = Tensor::f32(vec![3, 2], vec![1., 0., 0., 1., 1., 1.]);
        let r1 = interp::run(&g, &[xa.clone(), xb.clone()]).unwrap();
        let r2 = interp::run(&g2, &[xa, xb]).unwrap();
        assert_eq!(r1[0].as_f32(), r2[0].as_f32());
    }

    #[test]
    fn rebuild_keeps_io_order() {
        let mut g = Graph::new("t");
        let a = g.input("a", vec![1]);
        let b = g.input("b", vec![1]);
        let s = g.add(a, b, "s");
        g.output(s);
        g.output(a);
        let g2 = rebuild(&g, |_, _, _| None);
        assert_eq!(g2.inputs.len(), 2);
        assert_eq!(g2.outputs.len(), 2);
        assert_eq!(g2.node(g2.inputs[0]).name, "a");
    }
}
