//! Randomized differential verification of graph rewrites.
//!
//! A pass is trusted only if `exec(original) ≈ exec(rewritten)` on
//! random inputs — run for every pass on every model graph by the test
//! suite, and available at runtime via `xamba profile --verify`.
//! Both graphs go through the planned-executor [`Backend`] seam: each is
//! compiled once and executed per trial, which also makes every
//! differential run an arena-reuse test of the `ExecutionPlan`.

use crate::exec::{Backend, Plan, PlannedBackend};
use crate::graph::{DType, Graph, Op, Tensor};
use crate::util::Prng;

/// Outcome of one differential run.
#[derive(Clone, Debug)]
pub struct VerifyReport {
    pub outputs: usize,
    pub max_abs_err: f32,
    pub max_rel_err: f32,
}

/// Generate a random input set for a graph. i32 inputs are bounded by the
/// smallest gather-table first-dimension they index (token ids stay in
/// vocab range).
pub fn random_inputs(g: &Graph, rng: &mut Prng, scale: f32) -> Vec<Tensor> {
    // find an upper bound for every i32 input from its gather consumers
    let mut bounds: Vec<usize> = vec![usize::MAX; g.nodes.len()];
    for node in &g.nodes {
        if let Op::Gather = node.op {
            let data_dim = g.shape(node.inputs[0])[0];
            let idx = node.inputs[1];
            bounds[idx] = bounds[idx].min(data_dim);
        }
    }
    g.inputs
        .iter()
        .map(|&id| {
            let node = g.node(id);
            let n: usize = node.shape.iter().product();
            match node.dtype {
                DType::F32 => {
                    let data: Vec<f32> =
                        (0..n).map(|_| rng.normal() * scale).collect();
                    Tensor::f32(node.shape.clone(), data)
                }
                DType::I32 => {
                    let hi = if bounds[id] == usize::MAX { 16 } else { bounds[id] };
                    let data: Vec<i32> =
                        (0..n).map(|_| rng.below(hi.max(1)) as i32).collect();
                    Tensor::i32(node.shape.clone(), data)
                }
                // reduced-precision inputs: draw f32, convert (quantized
                // graphs declare their weight inputs f16/i8)
                DType::F16 | DType::I8 => {
                    let data: Vec<f32> =
                        (0..n).map(|_| rng.normal() * scale).collect();
                    Tensor::f32(node.shape.clone(), data).to_dtype(node.dtype)
                }
            }
        })
        .collect()
}

/// Run both graphs on `trials` random input sets; return the worst errors.
/// Errors out on shape mismatches or interpreter failures.
pub fn differential(
    original: &Graph,
    rewritten: &Graph,
    trials: usize,
    seed: u64,
    scale: f32,
) -> Result<VerifyReport, String> {
    if original.inputs.len() != rewritten.inputs.len() {
        return Err("input arity changed".into());
    }
    if original.outputs.len() != rewritten.outputs.len() {
        return Err("output arity changed".into());
    }
    let mut rng = Prng::new(seed);
    let mut max_abs = 0.0f32;
    let mut max_rel = 0.0f32;
    let mut plan_a = PlannedBackend.plan(original)?;
    let mut plan_b = PlannedBackend.plan(rewritten)?;
    for trial in 0..trials {
        let inputs = random_inputs(original, &mut rng, scale);
        let a = plan_a.execute(&inputs)?;
        let b = plan_b.execute(&inputs)?;
        for (i, (ta, tb)) in a.iter().zip(&b).enumerate() {
            if ta.shape != tb.shape {
                return Err(format!(
                    "trial {trial} output {i}: shape {:?} vs {:?}",
                    ta.shape, tb.shape
                ));
            }
            for (&x, &y) in ta.as_f32().iter().zip(tb.as_f32()) {
                let abs = (x - y).abs();
                max_abs = max_abs.max(abs);
                if x.abs() > 1e-3 {
                    max_rel = max_rel.max(abs / x.abs());
                }
                if x.is_nan() != y.is_nan() {
                    return Err(format!("trial {trial} output {i}: NaN mismatch"));
                }
            }
        }
    }
    Ok(VerifyReport { outputs: original.outputs.len(), max_abs_err: max_abs, max_rel_err: max_rel })
}

/// Assert a rewrite is exact to float tolerance (CumBA / ReduBA).
pub fn assert_exact(original: &Graph, rewritten: &Graph, tol: f32) {
    let r = differential(original, rewritten, 3, 0xD1FF, 0.5)
        .unwrap_or_else(|e| panic!("verify {}: {e}", original.name));
    assert!(
        r.max_abs_err <= tol,
        "{}: rewrite drifted: max_abs_err {} > {tol}",
        original.name,
        r.max_abs_err
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::passes::{cumba::CumbaPass, reduba::RedubaPass, Pass};

    #[test]
    fn detects_a_broken_rewrite() {
        let mut g = Graph::new("ok");
        let x = g.input("x", vec![3, 3]);
        let y = g.cumsum(x, 0, "cs");
        g.output(y);
        // "rewrite" that actually changes semantics: reduce instead of scan
        let mut bad = Graph::new("bad");
        let xb = bad.input("x", vec![3, 3]);
        let yb = bad.add(xb, xb, "wrong");
        bad.output(yb);
        let r = differential(&g, &bad, 2, 7, 1.0).unwrap();
        assert!(r.max_abs_err > 0.1);
    }

    #[test]
    fn passes_are_exact_on_mixed_graph() {
        let mut g = Graph::new("mixed");
        let x = g.input("x", vec![6, 5]);
        let c = g.cumsum(x, 0, "cs");
        let r = g.reduce_sum(c, 1, "rs");
        g.output(r);
        let g2 = CumbaPass.apply(&g);
        let g3 = RedubaPass.apply(&g2);
        assert_exact(&g, &g3, 1e-4);
    }

    #[test]
    fn token_inputs_respect_vocab_bound() {
        let mut g = Graph::new("g");
        let emb = g.input("emb", vec![10, 4]);
        let toks = g.input_i32("tokens", vec![32]);
        let e = g.gather(emb, toks, "embed");
        g.output(e);
        let mut rng = Prng::new(1);
        for _ in 0..10 {
            let inputs = random_inputs(&g, &mut rng, 1.0);
            for &t in inputs[1].as_i32() {
                assert!((0..10).contains(&t));
            }
        }
    }
}
