//! Table-1 substitute: quality of PLU (ActiBA) model variants.
//!
//! Evaluates the trained tiny char-LMs on held-out synthetic corpus with
//! exact activations vs ActiBA C-LUTs of 8/16/32 segments, reporting
//! next-byte PPL, top-1 accuracy, and logit drift — the offline analogue
//! of the paper's Table 1 (see DESIGN.md §1 for the substitution
//! rationale).
//!
//! Run: `cargo run --release --example quality_eval -- [--windows 24]`

use xamba::cli::Args;
use xamba::config::presets;
use xamba::models::{self, params};
use xamba::passes::{actiba::ActibaPass, Pass};
use xamba::quality::eval_lm;
use xamba::util::{corpus, Table};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv).expect("args");
    let max_windows = args.get_usize("windows").unwrap_or(24);
    // window evaluation is data-parallel on the exec pool; results are
    // bitwise-identical at every worker count
    let workers = args.get_usize("workers").unwrap_or(4);
    let window = 64usize;
    // held-out: seed differs from train.make_corpus(seed=7)
    let text = corpus::corpus(2500, 1234);

    let mut table = Table::new(&[
        "Model", "PPL ↓", "ACC ↑", "logit MAE", "logit max|Δ|",
    ])
    .with_title("Table-1 substitute: ActiBA PLU variants vs exact (held-out corpus)");

    for name in ["tiny-mamba", "tiny-mamba2"] {
        let shape = presets::model_by_name(name).unwrap();
        let weights =
            params::load_f32_bin(&format!("artifacts/weights_{name}.bin"))
                .expect("weights (run `make artifacts`)");
        let g = models::build_prefill(&shape, window);
        let (exact_rep, exact_logits) =
            eval_lm(&shape, &g, &weights, &text, window, max_windows, None, workers)
                .expect("exact eval");
        table.row(&[
            format!("{name} (exact)"),
            format!("{:.3}", exact_rep.ppl),
            format!("{:.4}", exact_rep.top1),
            "-".into(),
            "-".into(),
        ]);
        for segments in [8usize, 16, 32] {
            let gp = ActibaPass::with_segments(segments).apply(&g);
            let (rep, _) = eval_lm(
                &shape, &gp, &weights, &text, window, max_windows,
                Some(&exact_logits), workers,
            )
            .expect("plu eval");
            table.row(&[
                format!("{name} PLU-{segments}"),
                format!("{:.3}", rep.ppl),
                format!("{:.4}", rep.top1),
                format!("{:.4}", rep.logit_mae),
                format!("{:.3}", rep.logit_max),
            ]);
        }
    }
    println!("{table}");
    println!(
        "(paper Table 1: max degradation < 1.5% for 130M models, ~0 for larger;\n\
         the PLU-32 rows here are the configuration ActiBA ships.)\n"
    );

    // in-context recall probe: does the recurrent state actually carry
    // context, and does ActiBA preserve that ability?
    let mut t2 = Table::new(&["model", "acc 1st copy", "acc 2nd copy", "recall gain"])
        .with_title("Induction probe: repeated sentence in one window");
    for name in ["tiny-mamba", "tiny-mamba2"] {
        let shape = presets::model_by_name(name).unwrap();
        let weights =
            params::load_f32_bin(&format!("artifacts/weights_{name}.bin")).unwrap();
        for (label, segs) in [("exact", None), ("PLU-32", Some(32usize))] {
            let g = models::build_prefill(&shape, window);
            let g = match segs {
                None => g,
                Some(k) => ActibaPass::with_segments(k).apply(&g),
            };
            let (a1, a2) = xamba::quality::induction_probe(
                &shape, &g, &weights, window, 12, 42, workers,
            )
            .expect("induction probe");
            t2.row(&[
                format!("{name} ({label})"),
                format!("{a1:.3}"),
                format!("{a2:.3}"),
                format!("{:+.3}", a2 - a1),
            ]);
        }
    }
    println!("{t2}");
}
