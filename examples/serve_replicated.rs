//! Replicated serving demo: a router-fronted fleet of planned-backend
//! engines with session affinity and a rolling restart under load.
//!
//! Concurrent multi-turn conversations stream through the fleet; each
//! follow-up turn carries its `session_id`, so the router pins it to
//! the replica holding the conversation's recurrent state and the turn
//! resumes from the prefix cache in O(new tokens). Midway through the
//! traffic, replica 0 is drain-restarted — dispatch flows around it and
//! nothing is dropped. The demo ends with per-replica status and the
//! fleet-aggregated metrics report.
//!
//! Run: `cargo run --release --example serve_replicated --
//!       [--replicas 3] [--replica-dtypes f32,f16,i8]
//!       [--sessions 6] [--turns 3] [--speculate 2]`

use std::time::{Duration, Instant};

use xamba::cli::Args;
use xamba::config::ServeConfig;
use xamba::coordinator::{start_planned_router, FinishReason, GenParams, Router};
use xamba::util::Table;

fn status_table(router: &Router, title: &str) -> Table {
    let mut t = Table::new(&[
        "replica", "healthy", "ready", "inflight", "admitted", "completed",
        "spec accept",
    ])
    .with_title(title);
    for s in router.replica_status() {
        t.row(&[
            s.descriptor.clone(),
            s.healthy.to_string(),
            s.ready.to_string(),
            format!("{} req / {} tok", s.inflight_requests, s.inflight_tokens),
            s.metrics.admitted.to_string(),
            s.metrics.completed.to_string(),
            format!("{:.2}", s.metrics.spec_acceptance_rate()),
        ]);
    }
    t
}

fn run_turn(router: &Router, histories: &mut [Vec<u8>], tokens: &mut usize) {
    let rxs: Vec<_> = histories
        .iter()
        .enumerate()
        .map(|(i, h)| {
            router.submit(
                h,
                GenParams {
                    max_new_tokens: 8,
                    session_id: Some(i as u64),
                    ..Default::default()
                },
            )
        })
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let r = rx.recv_timeout(Duration::from_secs(300)).expect("turn response");
        assert_ne!(r.finish, FinishReason::Failed, "fleet dropped a turn");
        *tokens += r.generated.len();
        histories[i].extend_from_slice(&r.generated);
        histories[i].extend_from_slice(b" tell me more.");
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv).expect("args");
    let replicas = args.get_usize("replicas").unwrap_or(3);
    let sessions = args.get_usize("sessions").unwrap_or(6);
    let turns = args.get_usize("turns").unwrap_or(3).max(2);
    let dtypes: Vec<String> = args
        .get("replica-dtypes")
        .map(|s| {
            s.split(',')
                .map(|d| d.trim().to_string())
                .filter(|d| !d.is_empty())
                .collect()
        })
        .unwrap_or_default();

    // speculative decoding across the fleet (greedy turns draft via
    // prompt-lookup; the status table shows each replica's hit rate)
    let speculate = args.get_usize("speculate").unwrap_or(2) as i64;
    let cfg = ServeConfig {
        replicas,
        replica_dtypes: dtypes,
        max_slots: 8,
        queue_cap: 64,
        prefill_window: 16,
        prefill_chunk: 8,
        speculate,
        ..Default::default()
    };
    println!(
        "serve_replicated: {replicas} replicas, {sessions} sessions x {turns} turns\n"
    );
    let router = match start_planned_router(&cfg) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("cannot start the fleet: {e:#}");
            std::process::exit(1);
        }
    };

    let mut histories: Vec<Vec<u8>> = (0..sessions)
        .map(|i| format!("conversation {i:02} begins here.").into_bytes())
        .collect();
    let mut tokens = 0usize;
    let t0 = Instant::now();

    // first turns establish the pins and spread the fleet
    run_turn(&router, &mut histories, &mut tokens);
    println!("{}", status_table(&router, "fleet after turn 1"));

    // rolling restart under load: replica 0 drains, its in-flight work
    // finishes, a fresh engine takes its slot; traffic keeps flowing
    router.restart(0);
    for _ in 1..turns {
        run_turn(&router, &mut histories, &mut tokens);
    }
    let deadline = Instant::now() + Duration::from_secs(30);
    while !router.replica_status().first().map(|s| s.ready).unwrap_or(false) {
        if Instant::now() >= deadline {
            eprintln!("replica 0 never returned to rotation");
            std::process::exit(1);
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    println!("{}", status_table(&router, "fleet after the rolling restart"));

    let wall = t0.elapsed().as_secs_f64();
    let m = router.shutdown();
    println!(
        "throughput {:.1} tok/s aggregate | affinity hits {} | resumed tokens {} | \
         rebalanced {} | spec acceptance {:.2} ({} of {} drafts)",
        tokens as f64 / wall,
        m.affinity_hits,
        m.resumed_tokens,
        m.router_rebalanced,
        m.spec_acceptance_rate(),
        m.spec_accepted,
        m.spec_proposed
    );
    println!("{}", m.report());
}
