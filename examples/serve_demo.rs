//! End-to-end serving driver (DESIGN.md "E2E liveness" experiment).
//!
//! Loads the tiny trained char-LM through the full stack — AOT HLO
//! artifacts -> PJRT runtime -> coordinator (admission, state cache,
//! bucketed batcher) — replays a Poisson arrival trace of corpus-style
//! prompts from concurrent client threads, and reports latency
//! percentiles, Tokens/s, and batching efficiency for the baseline vs
//! xamba variants.
//!
//! Run: `cargo run --release --example serve_demo -- [--requests 48]
//!       [--rate 20] [--model tiny-mamba] [--variant both]`

use std::time::Duration;

use xamba::cli::Args;
use xamba::config::ServeConfig;
use xamba::coordinator::{start_pjrt, FinishReason, GenParams};
use xamba::util::{corpus, Prng, Summary};

fn run_variant(model: &str, variant: &str, n_requests: usize, rate: f64) {
    let cfg = ServeConfig {
        model: model.to_string(),
        variant: variant.to_string(),
        max_slots: 16,
        queue_cap: 128,
        ..Default::default()
    };
    let server = match start_pjrt(&cfg) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("cannot start {model}.{variant}: {e:#} (run `make artifacts`)");
            std::process::exit(1);
        }
    };

    // Poisson arrivals from 4 client threads
    let server = std::sync::Arc::new(server);
    let t0 = std::time::Instant::now();
    let mut clients = Vec::new();
    let per_client = n_requests / 4;
    for c in 0..4u64 {
        let s = server.clone();
        clients.push(std::thread::spawn(move || {
            let mut rng = Prng::new(100 + c);
            let mut results = Vec::new();
            for i in 0..per_client {
                let wait = rng.exponential(rate / 4.0);
                std::thread::sleep(Duration::from_secs_f64(wait.min(0.5)));
                let p = corpus::prompt(&mut rng);
                let rx = s.submit(
                    &p,
                    GenParams {
                        max_new_tokens: 32,
                        temperature: 0.0,
                        stop_byte: Some(b'.'),
                        seed: c * 1000 + i as u64,
                        ..Default::default()
                    },
                );
                if let Ok(r) = rx.recv_timeout(Duration::from_secs(120)) {
                    results.push(r);
                }
            }
            results
        }));
    }
    let mut responses = Vec::new();
    for c in clients {
        responses.extend(c.join().expect("client thread"));
    }
    let wall = t0.elapsed().as_secs_f64();

    let ok: Vec<_> = responses
        .iter()
        .filter(|r| r.finish != FinishReason::Rejected)
        .collect();
    let ttfts: Vec<f64> = ok.iter().map(|r| r.ttft_us / 1e3).collect();
    let e2es: Vec<f64> = ok.iter().map(|r| r.e2e_us / 1e3).collect();
    let total_tokens: usize = ok.iter().map(|r| r.generated.len()).sum();
    let st = Summary::of(&ttfts);
    let se = Summary::of(&e2es);
    let m = server.metrics();

    println!("--- {model} [{variant}] ---");
    println!(
        "completed {}/{} requests in {wall:.2}s wall  ({} rejected)",
        ok.len(),
        responses.len(),
        responses.len() - ok.len()
    );
    println!(
        "throughput {:.1} tok/s aggregate  | mean decode batch {:.2}",
        total_tokens as f64 / wall,
        m.mean_decode_batch()
    );
    println!(
        "TTFT ms   p50 {:.1}  p90 {:.1}  p99 {:.1}",
        st.p50, st.p90, st.p99
    );
    println!(
        "e2e  ms   p50 {:.1}  p90 {:.1}  p99 {:.1}",
        se.p50, se.p90, se.p99
    );
    // show a couple of completions to prove the model learned the corpus
    for r in ok.iter().take(3) {
        println!(
            "  {:?} -> {:?}",
            String::from_utf8_lossy(&r.prompt),
            String::from_utf8_lossy(&r.generated)
        );
    }
    println!();
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv).expect("args");
    let n = args.get_usize("requests").unwrap_or(48);
    let rate = args.get_f32("rate").unwrap_or(20.0) as f64;
    let model = args.get("model").unwrap_or("tiny-mamba").to_string();
    let variant = args.get("variant").unwrap_or("both").to_string();
    println!(
        "serve_demo: {n} requests, Poisson rate {rate}/s, model {model}\n"
    );
    if variant == "both" {
        run_variant(&model, "baseline", n, rate);
        run_variant(&model, "xamba", n, rate);
    } else {
        run_variant(&model, &variant, n, rate);
    }
}
