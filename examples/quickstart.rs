//! Quickstart: load the AOT artifacts, run one prefill and a few decode
//! steps by hand. The 60-second tour of the public API.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use xamba::coordinator::{sample, Tokenizer};
use xamba::runtime::{Engine, HostTensor, Manifest};
use xamba::util::Prng;

fn main() -> anyhow::Result<()> {
    // 1. the manifest describes every AOT-compiled program
    let manifest = Manifest::load("artifacts").map_err(anyhow::Error::msg)?;
    let prefill = manifest.find("tiny-mamba", "xamba", "prefill").unwrap();
    let decode = manifest.find("tiny-mamba", "xamba", "decode_b1").unwrap();
    println!(
        "loaded {} programs; using {} + {}",
        manifest.programs.len(),
        prefill.hlo_file,
        decode.hlo_file
    );

    // 2. compile on the PJRT CPU client (cached by program key)
    let mut engine = Engine::cpu()?;

    // 3. fixed-window prefill: left-padded prompt, zero states
    let tok = Tokenizer::new(manifest.prefill_len, prefill.shape.vocab_size);
    let prompt = b"every kernel needs a";
    let ids = tok.encode_window(prompt);
    let outs = engine.run_with_weights(
        &manifest,
        prefill,
        &[
            HostTensor::I32(vec![ids.len()], ids),
            HostTensor::zeros(&prefill.inputs[2].shape),
            HostTensor::zeros(&prefill.inputs[3].shape),
        ],
    )?;
    let mut rng = Prng::new(0);
    let mut token = sample(outs[0].f32_data(), 0.0, &mut rng);
    let (mut conv, mut ssm) = (outs[1].clone(), outs[2].clone());

    // 4. decode loop: one token at a time from the cached SSM state
    let mut text = vec![token as u8];
    for _ in 0..24 {
        let with_batch = |t: &HostTensor| {
            let mut s = vec![1usize];
            s.extend_from_slice(t.shape());
            HostTensor::F32(s, t.f32_data().to_vec())
        };
        let outs = engine.run_with_weights(
            &manifest,
            decode,
            &[
                HostTensor::I32(vec![1, 1], vec![token]),
                with_batch(&conv),
                with_batch(&ssm),
            ],
        )?;
        token = sample(outs[0].f32_data(), 0.0, &mut rng);
        text.push(token as u8);
        let strip = |t: &HostTensor| {
            HostTensor::F32(t.shape()[1..].to_vec(), t.f32_data().to_vec())
        };
        conv = strip(&outs[1]);
        ssm = strip(&outs[2]);
    }
    println!(
        "prompt:     {:?}\ncompletion: {:?}",
        String::from_utf8_lossy(prompt),
        String::from_utf8_lossy(&text)
    );
    Ok(())
}
