//! Debug harness: run individually-lowered Pallas kernel HLOs (dumped to
//! /tmp by a scratch python script) on the rust PJRT engine and compare
//! against python's outputs. Used to isolate HLO-interchange issues.
//!
//! Goes through `runtime::Engine` + `HostTensor` like every other
//! consumer — nothing above the runtime layer touches the `xla` crate.

use xamba::runtime::{Engine, HostTensor};
use xamba::util::json::Json;

fn main() -> anyhow::Result<()> {
    let meta = Json::parse(&std::fs::read_to_string("/tmp/k_meta.json")?)
        .map_err(|e| anyhow::anyhow!(e))?;
    let engine = Engine::cpu()?;
    let Json::Obj(cases) = &meta else { panic!() };
    for (name, case) in cases {
        let mut args = Vec::new();
        for a in case.get("args").unwrap().as_arr().unwrap() {
            let shape: Vec<usize> = a
                .get("shape")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|d| d.as_f64().unwrap() as usize)
                .collect();
            let data: Vec<f32> = a
                .get("data")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|x| x.as_f64().unwrap() as f32)
                .collect();
            args.push(HostTensor::F32(shape, data));
        }
        let outs = engine.run_hlo_file(&format!("/tmp/k_{name}.hlo.txt"), &args)?;
        for (i, (part, want)) in outs
            .iter()
            .zip(case.get("outs").unwrap().as_arr().unwrap())
            .enumerate()
        {
            let got = part.f32_data();
            let head: Vec<f32> = want
                .get("head")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|x| x.as_f64().unwrap() as f32)
                .collect();
            let sum: f64 = got.iter().map(|&x| x as f64).sum();
            let want_sum = want.get("sum").unwrap().as_f64().unwrap();
            let ok = got
                .iter()
                .zip(&head)
                .all(|(a, b)| (a - b).abs() < 1e-3 + 1e-3 * b.abs())
                && (sum - want_sum).abs() < 1e-2 * want_sum.abs().max(1.0);
            println!(
                "{name}[{i}]: {}  rust_head={:?} py_head={:?} rust_sum={sum:.3} py_sum={want_sum:.3}",
                if ok { "OK " } else { "MISMATCH" },
                &got[..4.min(got.len())],
                &head[..4.min(head.len())]
            );
        }
    }
    Ok(())
}
