//! Fig-1-style NPU profiling: per-op latency breakdowns for Mamba and
//! Mamba-2 blocks (130M shapes, T=4 prefill — the paper's workload), on
//! the simulated Series-2 NPU, before and after the XAMBA passes.
//!
//! Run: `cargo run --release --example npu_profile`

use xamba::config::{npu_series2, presets};
use xamba::graph::Census;
use xamba::npu::Profile;
use xamba::passes::{actiba::ActibaPass, cumba::CumbaPass, reduba::RedubaPass, Pass};

fn main() {
    let cfg = npu_series2();
    let t = 4; // the paper's fixed input-token count

    println!("=== Fig 1: baseline bottlenecks (130M shapes, T={t}) ===\n");
    for shape in [presets::block130m_mamba(), presets::block130m_mamba2()] {
        let g = xamba::models::build_block(&shape, t);
        let p = Profile::of(&cfg, &g);
        println!("{}", p.breakdown_table());
        println!(
            "DSP share {:.1}%  MPU share {:.1}%\n",
            100.0 * p.engine_share(xamba::npu::Engine::Dsp),
            100.0 * p.engine_share(xamba::npu::Engine::Mpu),
        );
    }

    println!("=== Mamba-2 block after CumBA / ReduBA (Fig 4a/4b) ===\n");
    let m2 = presets::block130m_mamba2();
    let g = xamba::models::build_block(&m2, t);
    let base = Profile::of(&cfg, &g);
    let cumba = Profile::of(&cfg, &CumbaPass.apply(&g));
    let reduba = Profile::of(&cfg, &RedubaPass.apply(&g));
    let both = Profile::of(&cfg, &RedubaPass.apply(&CumbaPass.apply(&g)));
    println!(
        "baseline {:.3} ms | CumBA {:.3} ms ({:.2}x) | ReduBA {:.3} ms ({:.2}x) | both {:.3} ms ({:.2}x)\n",
        base.total_ns / 1e6,
        cumba.total_ns / 1e6,
        base.total_ns / cumba.total_ns,
        reduba.total_ns / 1e6,
        base.total_ns / reduba.total_ns,
        both.total_ns / 1e6,
        base.total_ns / both.total_ns,
    );
    println!("{}", both.breakdown_table());

    println!("=== Mamba block after ActiBA (Fig 4c) ===\n");
    let m1 = presets::block130m_mamba();
    let g1 = xamba::models::build_block(&m1, t);
    let b1 = Profile::of(&cfg, &g1);
    let sp = Profile::of(&cfg, &ActibaPass::softplus_only(32).apply(&g1));
    let full = Profile::of(&cfg, &ActibaPass::default().apply(&g1));
    println!(
        "baseline {:.3} ms | +softplus PLU {:.3} ms ({:.2}x) | +SiLU PLU {:.3} ms ({:.2}x)\n",
        b1.total_ns / 1e6,
        sp.total_ns / 1e6,
        b1.total_ns / sp.total_ns,
        full.total_ns / 1e6,
        b1.total_ns / full.total_ns,
    );
    println!("{}", full.breakdown_table());

    println!("=== Fig 5: operator census ===\n");
    let c1 = Census::of(&xamba::models::build_block(&m1, t));
    let c2 = Census::of(&xamba::models::build_block(&m2, t));
    println!(
        "{}",
        Census::comparison_table(&[("mamba(T=4)", &c1), ("mamba2(T=4)", &c2)])
    );
}
